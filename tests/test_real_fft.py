"""Real-transform subsystem (repro.real): packed two-for-one r2c/c2r vs
numpy, the embed fallback, the Pallas Hermitian kernels, the guarded
half-slice, per-stage local_impl, and the r2c problem class in the tuner.

Single-device checks run in-process; multi-device and float64 checks run
on 8 virtual CPU devices in subprocesses (see conftest.run_multidevice).
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import REPO, SRC, run_multidevice
from repro.core import Decomposition, FFTOptions
from repro.core.rfft import rfft3d, irfft3d
from repro import real as real_lib
from repro.real import packing
from repro import tuning

SIZES = {"data": 2, "model": 4}


# --- local packed path vs numpy ---------------------------------------------

@pytest.mark.parametrize("shape,impl", [
    ((8, 4, 16), "matmul"),      # even everything, pow2
    ((4, 8, 32), "matmul"),      # pairs along y
    ((8, 4, 15), "xla"),         # odd Nz: fold-free two-for-one
    ((9, 6, 15), "xla"),         # odd Nx/Nz
    ((8, 9, 12), "xla"),         # odd Ny: pairs along x instead
])
def test_local_packed_matches_rfftn(shape, impl, rng):
    x = rng.randn(*shape).astype(np.float32)
    opts = FFTOptions(local_impl=impl)
    y = np.asarray(rfft3d(jnp.asarray(x), opts=opts, strategy="packed"))
    ref = np.fft.rfftn(x)
    assert y.shape == ref.shape
    np.testing.assert_allclose(y, ref, atol=3e-5 * np.abs(ref).max())
    xb = np.asarray(irfft3d(jnp.asarray(y), shape[-1], opts=opts,
                            strategy="packed"))
    np.testing.assert_allclose(xb, x, atol=2e-5)


def test_local_packed_equals_embed(rng):
    x = rng.randn(16, 8, 32).astype(np.float32)
    yp = np.asarray(rfft3d(jnp.asarray(x), strategy="packed"))
    ye = np.asarray(rfft3d(jnp.asarray(x), strategy="embed"))
    np.testing.assert_allclose(yp, ye, atol=2e-5 * np.abs(ye).max())


def test_strategy_resolution(rng):
    # all-odd (Nx, Ny): no pairing axis -> explicit packed raises, auto
    # falls back to the (always valid) embedding and still matches numpy
    x = rng.randn(9, 9, 15).astype(np.float32)
    opts = FFTOptions(local_impl="xla")
    with pytest.raises(ValueError, match="packed"):
        rfft3d(jnp.asarray(x), opts=opts, strategy="packed")
    y = np.asarray(rfft3d(jnp.asarray(x), opts=opts))  # auto
    np.testing.assert_allclose(y, np.fft.rfftn(x),
                               atol=3e-5 * np.abs(np.fft.rfftn(x)).max())
    with pytest.raises(ValueError, match="strategy"):
        rfft3d(jnp.asarray(x), opts=opts, strategy="bogus")


def test_rfft3d_rejects_complex(rng):
    with pytest.raises(ValueError, match="real"):
        rfft3d(jnp.ones((4, 4, 4), jnp.complex64))


@pytest.mark.parametrize("nz", [8, 15])
def test_c2r_non_hermitian_input_matches_irfftn(nz, rng):
    """irfftn implicitly projects the DC/Nyquist planes of a non-Hermitian
    half spectrum; the packed path must apply the same projection (e.g.
    derivative filters 1j*kx leave a surviving anti-Hermitian Nyquist
    plane — the Burgers driver's exact usage)."""
    n = 8
    x = rng.randn(n, n, nz)
    kx = np.fft.fftfreq(n, d=1.0 / n)[:, None, None]
    y = (1j * kx * np.fft.rfftn(x) * (1 + 0.3j)).astype(np.complex64)
    axes = [0, 1, 2]
    ref = np.fft.irfftn(y, s=(n, n, nz), axes=axes)
    opts = FFTOptions(local_impl="xla")
    for strat in ("packed", "embed"):
        got = np.asarray(irfft3d(jnp.asarray(y), nz, opts=opts,
                                 strategy=strat))
        np.testing.assert_allclose(got, ref, atol=2e-6 * np.abs(ref).max(),
                                   err_msg=strat)


@pytest.mark.parametrize("norm", ["ortho", "backward", None])
def test_local_norm_roundtrips(norm, rng):
    """r2c norm semantics match numpy on both strategies (satellite:
    normalization coverage)."""
    x = rng.randn(8, 8, 16).astype(np.float32)
    np_norm = norm if norm is not None else "backward"
    ref = np.fft.rfftn(x, norm=np_norm)
    for strat in ("packed", "embed"):
        y = np.asarray(rfft3d(jnp.asarray(x), strategy=strat, norm=norm))
        np.testing.assert_allclose(y, ref, atol=3e-5 * np.abs(ref).max(),
                                   err_msg=f"{strat}/{norm}")
        xb = np.asarray(irfft3d(jnp.asarray(y), 16, strategy=strat,
                                norm=norm))
        np.testing.assert_allclose(xb, x, atol=2e-5,
                                   err_msg=f"{strat}/{norm}")


# --- packing primitives ------------------------------------------------------

def test_pack_unpack_two_for_one_identity(rng):
    """unpack(FFT(pack(x))) splits exactly into the two pencils' FFTs."""
    a = rng.randn(3, 16).astype(np.float32)
    b = rng.randn(3, 16).astype(np.float32)
    x = np.concatenate([a, b], axis=0)          # pair axis 0: halves
    c = packing.pack_two(jnp.asarray(x), 0)
    C = jnp.fft.fft(c, axis=-1)
    S = packing.unpack_two(C, 0, nh=9)
    np.testing.assert_allclose(np.asarray(S[:3]), np.fft.rfft(a, axis=-1),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(S[3:]), np.fft.rfft(b, axis=-1),
                               atol=1e-4)


@pytest.mark.parametrize("folded", [True, False])
def test_repack_inverts_unpack(folded, rng):
    nz = 32
    x = rng.randn(6, nz).astype(np.float32)     # 3 pairs
    C = jnp.fft.fft(packing.pack_two(jnp.asarray(x), 0), axis=-1)
    S = packing.unpack_two(C, 0, nh=nz // 2 + 1, fold=folded)
    C2 = packing.repack_halves(S, 0, nz, folded=folded)
    np.testing.assert_allclose(np.asarray(C2), np.asarray(C), atol=1e-4)
    xb = packing.split_pairs(jnp.fft.ifft(C2, axis=-1), 0)
    np.testing.assert_allclose(np.asarray(xb), x, atol=1e-5)


# --- Pallas Hermitian kernels vs the jnp reference ---------------------------

@pytest.mark.parametrize("n", [16, 64, 256])
def test_hermitian_kernels_match_reference(n, rng):
    C = (rng.randn(8, 4, n) + 1j * rng.randn(8, 4, n)).astype(np.complex64)
    Cj = jnp.asarray(C)
    ref = packing.unpack_two(Cj, 1, fold=True, use_pallas=False)
    ker = packing.unpack_two(Cj, 1, fold=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-6)
    ref2 = packing.repack_halves(ref, 1, n, folded=True, use_pallas=False)
    ker2 = packing.repack_halves(ref, 1, n, folded=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(ker2), np.asarray(ref2), atol=1e-6)


def test_pallas_impl_end_to_end(rng):
    x = rng.randn(8, 8, 16).astype(np.float32)
    opts = FFTOptions(local_impl="pallas")
    y = np.asarray(rfft3d(jnp.asarray(x), opts=opts, strategy="packed"))
    ref = np.fft.rfftn(x)
    np.testing.assert_allclose(y, ref, atol=5e-5 * np.abs(ref).max())
    xb = np.asarray(irfft3d(jnp.asarray(y), 16, opts=opts, strategy="packed"))
    np.testing.assert_allclose(xb, x, atol=2e-5)


# --- per-stage local_impl ----------------------------------------------------

def test_fftoptions_stagewise_local_impl():
    o = FFTOptions(local_impl=("matmul", "stockham", "xla"))
    assert o.stage_impl(0) == "matmul" and o.stage_impl(2) == "xla"
    # homogeneous tuples collapse to the canonical scalar form
    assert FFTOptions(local_impl=("xla",) * 3).local_impl == "xla"
    # json round trip (lists re-tuple)
    o2 = FFTOptions(**json.loads(json.dumps(dataclasses.asdict(o))))
    assert o2 == o
    with pytest.raises(ValueError):
        FFTOptions(local_impl=("matmul", "xla"))


def test_stagewise_impl_local_3d(rng):
    from repro.core import local_fft as lf
    x = (rng.randn(8, 16, 32) + 1j * rng.randn(8, 16, 32)).astype(np.complex64)
    y = np.asarray(lf.fft3d_local(jnp.asarray(x),
                                  impl=("matmul", "stockham", "xla")))
    np.testing.assert_allclose(y, np.fft.fftn(x),
                               atol=2e-4 * np.abs(np.fft.fftn(x)).max())


def test_candidates_stagewise_and_r2c():
    het = tuning.enumerate_candidates((32, 32, 32), SIZES,
                                      heterogeneous_impls=True)
    tuples = [c for c in het if isinstance(c.opts.local_impl, tuple)]
    assert tuples and all(len(c.opts.local_impl) == 3 for c in tuples)
    assert all("-" in c.label for c in tuples)

    r2c = tuning.enumerate_candidates((32, 32, 32), SIZES, problem="r2c")
    strategies = {c.strategy for c in r2c}
    assert strategies == {"packed", "embed"}
    assert all(c.problem == "r2c" for c in r2c)
    # packed candidates only where the pipelines support them — pencil
    # (pair z-pencils) and, since the schedule refactor, slab (pair
    # x-lines); this divisible 32^3 problem must offer both
    packed_kinds = {c.decomp.kind for c in r2c if c.strategy == "packed"}
    assert packed_kinds == {"pencil", "slab"}
    for c in r2c:
        if c.strategy == "packed":
            assert real_lib.packed_unsupported_reason(
                (32, 32, 32), c.decomp, SIZES, c.opts) is None


def test_cost_model_packed_halves_roofline_terms():
    dec = Decomposition("pencil", ("data", "model"))
    opts = FFTOptions(output_layout="spectral")
    mk = lambda strat: tuning.Candidate(dec, opts, problem="r2c",
                                        strategy=strat)
    packed = tuning.analytic_cost((64,) * 3, mk("packed"), SIZES)
    embed = tuning.analytic_cost((64,) * 3, mk("embed"), SIZES)
    assert packed.flops == embed.flops / 2
    assert packed.local_bytes == embed.local_bytes / 2
    # 3 half-volume shuffles vs 2 full transposes
    assert packed.collective_bytes == 0.75 * embed.collective_bytes
    # at bandwidth-bound sizes packed dominates its embed counterpart,
    # and the model ranks the best pencil plan as a packed one (the
    # global winner may be a slab at low P, where one full-volume
    # transpose undercuts three half-volume shuffles — at scale the
    # P <= Nz slab wall leaves pencil-packed as the scalable choice)
    big_p = tuning.analytic_cost((256,) * 3, mk("packed"), SIZES)
    big_e = tuning.analytic_cost((256,) * 3, mk("embed"), SIZES)
    assert big_p.total_s < big_e.total_s
    r = tuning.tune((256,) * 3, axis_sizes=SIZES, mode="model", problem="r2c")
    assert r.problem == "r2c" and r.strategy in ("packed", "embed")
    pencil_rows = [row["label"] for row in r.ranked
                   if row["label"].startswith("pencil")]
    assert pencil_rows and pencil_rows[0].endswith("r2c-packed")


def test_stagewise_cost_uses_per_stage_efficiency():
    dec = Decomposition("pencil", ("data", "model"))
    fast = tuning.analytic_cost(
        (64,) * 3, tuning.Candidate(dec, FFTOptions(local_impl="matmul")),
        SIZES)
    mixed = tuning.analytic_cost(
        (64,) * 3, tuning.Candidate(
            dec, FFTOptions(local_impl=("matmul", "stockham", "matmul"))),
        SIZES)
    slow = tuning.analytic_cost(
        (64,) * 3, tuning.Candidate(dec, FFTOptions(local_impl="stockham")),
        SIZES)
    assert fast.compute_s < mixed.compute_s < slow.compute_s


# --- wisdom: problem dimension, strategy round trip, seed + CLI --------------

def test_wisdom_key_problem_dimension():
    k_c2c = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu")
    k_r2c = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu", "r2c")
    assert k_c2c != k_r2c and k_r2c.endswith("|r2c")
    assert k_c2c.count("|") == 3  # legacy four-field format preserved


def test_wisdom_entry_strategy_roundtrip(tmp_path):
    path = str(tmp_path / "w.json")
    cand = tuning.Candidate(Decomposition("pencil", ("data", "model")),
                            FFTOptions(output_layout="spectral",
                                       local_impl=("matmul", "xla", "xla")),
                            problem="r2c", strategy="packed")
    key = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "any", "r2c")
    w = tuning.Wisdom(path=path)
    w.record(key, tuning.WisdomEntry.from_candidate(cand, "measure",
                                                    measured_s=1e-3))
    w.save()
    got = tuning.Wisdom.load(path).lookup(key).candidate()
    assert got.problem == "r2c" and got.strategy == "packed"
    assert got.opts == cand.opts and got.decomp == cand.decomp


def test_wisdom_model_entries_newer_wins():
    """Merging an old wisdom file back in must not clobber fresher model
    entries (cost-model improvements propagate forward, not backward)."""
    cand_old = tuning.Candidate(Decomposition("slab", ("p",)), FFTOptions())
    cand_new = tuning.Candidate(Decomposition("pencil", ("a", "p")),
                                FFTOptions(overlap_k=4))
    old = tuning.WisdomEntry.from_candidate(cand_old, "model", model_s=1e-3)
    old.created = 100.0
    new = tuning.WisdomEntry.from_candidate(cand_new, "model", model_s=2e-3)
    new.created = 200.0
    w = tuning.Wisdom()
    w.record("k", new)
    w.record("k", old)          # stale entry arrives second
    assert w.lookup("k").created == 200.0
    # but a measured entry still beats any model entry, old or new
    meas = tuning.WisdomEntry.from_candidate(cand_old, "measure",
                                             measured_s=1e-3)
    w.record("k", meas)
    w.record("k", new)
    assert w.lookup("k").measured_s == 1e-3


def test_seed_wisdom_ships_and_cli_merges(tmp_path):
    seed = tuning.load_seed()
    assert len(seed) > 0
    assert any(k.endswith("|r2c") for k in seed.entries)
    # every shipped entry deserializes to a valid candidate
    for e in seed.entries.values():
        e.candidate()
    out = str(tmp_path / "merged.json")
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.tuning.wisdom", "merge", out, "--seed"],
        env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert len(tuning.Wisdom.load(out)) == len(seed)


# --- multi-device: packed vs numpy, guard, tuned r2c plan --------------------

def test_distributed_r2c_strategies_and_guard():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
rng = np.random.RandomState(42)
mesh = jax.make_mesh((2,4), ("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))

def check(shape, opts, strat, tag):
    x = rng.randn(*shape).astype(np.float32)
    ref = np.fft.rfftn(x)
    plan = Croft3D(shape, mesh, dec, opts, problem="r2c", strategy=strat)
    assert plan.strategy == strat
    xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
    y = plan.forward(xd)
    assert y.shape == ref.shape, (y.shape, ref.shape)
    err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
    xb = plan.inverse(y)
    assert not jnp.iscomplexobj(xb) or strat == "embed"
    rerr = float(jnp.max(jnp.abs(xb - x)))
    assert err < 1e-5, (tag, err)
    assert rerr < 1e-4, (tag, rerr)
    print("OK", tag, err, rerr)

N = 32
for strat in ("packed", "embed"):
    check((N,N,N), FFTOptions(), strat, strat)
    check((N,N,N), FFTOptions(overlap_k=1), strat, strat + "-k1")
check((N,N,N), FFTOptions(local_impl=("matmul","stockham","xla")),
      "packed", "packed-stagewise")
# guard: natural-layout embed slice where Nh % shard != 0 (Nz=8, Pz=4)
check((64, 16, 8), FFTOptions(), "embed", "embed-guard-odd-shard")
# spectral-layout embed (z already local: plain slice)
check((N,N,N), FFTOptions(output_layout="spectral"), "embed", "embed-spectral")
# packed refuses unsupported problems with a reason: (32, 4, 32) is
# c2c-valid but leaves one z-pencil per device — nothing to pair
try:
    Croft3D((N, 4, N), mesh, dec, FFTOptions(), problem="r2c",
            strategy="packed")
    raise SystemExit("packed should have been rejected for Ny=4")
except ValueError as e:
    assert "packed" in str(e)
    print("OK packed-rejection:", e)
# auto on the same problem falls back to embed
plan = Croft3D((N, 4, N), mesh, dec, FFTOptions(), problem="r2c")
assert plan.strategy == "embed"
print("OK auto-fallback")
# output_sharding keeps the odd-sized Nh axis local for every kind —
# including cell, whose spectral spec shards z; filters placed with it
# must be shardable (Nh=5 would not tile a z shard)
mesh222 = jax.make_mesh((2,2,2), ("a","b","c"),
                        axis_types=(jax.sharding.AxisType.Auto,)*3)
cplan = Croft3D((8, 8, 8), mesh222, Decomposition("cell", ("a","b","c")),
                FFTOptions(), problem="r2c")
assert cplan.output_sharding.spec[2] is None, cplan.output_sharding.spec
filt = jax.device_put(jnp.ones((8, 8, 5), jnp.complex64),
                      cplan.output_sharding)
xc = rng.randn(8, 8, 8).astype(np.float32)
yc = cplan.forward(jax.device_put(jnp.asarray(xc), cplan.input_sharding))
err = np.abs(np.asarray(yc) - np.fft.rfftn(xc)).max()
assert err < 1e-4, err
print("OK cell r2c + z-local output sharding")
""", timeout=900)


def test_distributed_packed_slab_and_norm():
    """The packed-slab strategy (pair x-lines, one half-volume z<->x
    transpose) on a 1-axis mesh: numpy parity, exact inverse, norm
    round trips, and the auto-resolution picking it."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.core.rfft import rfft3d, irfft3d
from jax.sharding import NamedSharding
rng = np.random.RandomState(5)
N = 32
mesh = jax.make_mesh((8,), ("p",), axis_types=(jax.sharding.AxisType.Auto,))
dec = Decomposition("slab", ("p",))
x = rng.randn(N, N, N).astype(np.float32)
ref = np.fft.rfftn(x)
plan = Croft3D((N,N,N), mesh, dec, FFTOptions(), problem="r2c")
assert plan.strategy == "packed"   # auto resolves to the slab pipeline
xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
y = plan.forward(xd)
err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
xb = plan.inverse(y)
rerr = float(jnp.max(jnp.abs(xb - x)))
assert err < 1e-5, err
assert rerr < 1e-4, rerr
print("OK packed-slab", err, rerr)
# K=1 and per-stage impls
for opts, tag in [(FFTOptions(overlap_k=1), "k1"),
                  (FFTOptions(local_impl=("matmul","stockham","xla")),
                   "stagewise")]:
    p2 = Croft3D((N,N,N), mesh, dec, opts, problem="r2c", strategy="packed")
    y2 = p2.forward(jax.device_put(jnp.asarray(x), p2.input_sharding))
    e2 = float(jnp.max(jnp.abs(y2 - ref))) / np.abs(ref).max()
    assert e2 < 1e-4, (tag, e2)
    print("OK packed-slab", tag, e2)
# norm round trips through the distributed packed pipelines
sh = NamedSharding(mesh, dec.spectral_spec())
for norm in ("ortho", "backward"):
    yn = rfft3d(jax.device_put(jnp.asarray(x), sh), mesh, dec,
                FFTOptions(), strategy="packed", norm=norm)
    refn = np.fft.rfftn(x, norm=norm)
    en = float(jnp.max(jnp.abs(yn - refn))) / np.abs(refn).max()
    xn = irfft3d(yn, N, mesh, dec, FFTOptions(), strategy="packed",
                 norm=norm)
    rn = float(jnp.max(jnp.abs(xn - x)))
    assert en < 1e-5 and rn < 1e-4, (norm, en, rn)
    print("OK packed-slab norm", norm, en, rn)
# unpairable local Nx is rejected with a reason
try:
    Croft3D((8, N, N), mesh, dec, FFTOptions(), problem="r2c",
            strategy="packed")
    raise SystemExit("packed-slab should reject Nx/P == 1")
except ValueError as e:
    assert "pair" in str(e)
    print("OK packed-slab rejection:", e)
""", timeout=900)


def test_distributed_r2c_float64_and_tuned():
    run_multidevice("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
mesh = jax.make_mesh((2,4), ("y","z"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.RandomState(7)
N = 32
x = rng.randn(N,N,N)
ref = np.fft.rfftn(x)
plan = Croft3D((N,N,N), mesh, Decomposition("pencil", ("y","z")),
               FFTOptions(), dtype=jnp.complex128, problem="r2c",
               strategy="packed")
assert plan.input_dtype == jnp.float64
xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
y = plan.forward(xd)
err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
assert err < 1e-12, err
xb = plan.inverse(y)
assert xb.dtype == jnp.float64
rerr = float(jnp.max(jnp.abs(xb - x)))
assert rerr < 1e-11, rerr
print("c128 packed fwd relerr", err, "roundtrip", rerr)

# tuned r2c plan: planner measures real-input candidates end to end
plan2 = Croft3D.tuned((N,N,N), mesh, mode="measure", problem="r2c",
                      top_k=2, measure_iters=2)
print("tuned:", plan2.tune_result.summary())
assert plan2.tune_result.problem == "r2c"
assert plan2.strategy in ("packed", "embed")
x32 = x.astype(np.float64)
y2 = plan2.forward(jax.device_put(jnp.asarray(x32), plan2.input_sharding))
err2 = float(jnp.max(jnp.abs(y2 - ref))) / np.abs(ref).max()
assert err2 < 1e-5, err2
print("OK tuned r2c", err2)
""", timeout=900)


def test_batched_packed_r2c_native_and_vmapped_measure():
    """Leading batch axes ride the packed pipeline natively (one schedule,
    batched collectives, one amortized DC/Nyquist unfold — no per-field
    vmap dispatch), vmap still works on top, and mode="measure" with
    batch=B times the vmapped transform (the ROADMAP follow-on)."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Croft3D, Decomposition, FFTOptions
from repro import tuning
mesh = jax.make_mesh((2,4), ("y","z"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("y","z"))
N, B = 32, 3
rng = np.random.RandomState(11)
xb = rng.randn(B, N, N, N).astype(np.float32)
ref = np.stack([np.fft.rfftn(xb[i]) for i in range(B)])
plan = Croft3D((N,N,N), mesh, dec, FFTOptions(), problem="r2c",
               strategy="packed")
sh = NamedSharding(mesh, P(None, *plan.input_sharding.spec))
xd = jax.device_put(jnp.asarray(xb), sh)

# native leading batch axis: one transform call over (B, Nx, Ny, Nz)
y = plan.forward(xd)
assert y.shape == (B, N, N, N//2 + 1), y.shape
err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
assert err < 1e-5, err
xb_back = plan.inverse(y)
rerr = float(jnp.max(jnp.abs(xb_back - xb)))
assert rerr < 1e-4, rerr

# the batched call compiles to the same collective COUNT as one field:
# the batch rides inside each launch instead of multiplying launches
from repro.launch import hlo_cost
def coll_count(fn, spec):
    c = jax.jit(fn).lower(spec).compile()
    a = hlo_cost.analyze(c.as_text())
    return sum(v["count"] for v in a.collectives.values())
s1 = jax.ShapeDtypeStruct((N,N,N), jnp.float32,
                          sharding=plan.input_sharding)
sB = jax.ShapeDtypeStruct((B,N,N,N), jnp.float32, sharding=sh)
n1, nB = coll_count(plan.forward, s1), coll_count(plan.forward, sB)
assert n1 == nB, (n1, nB)

# vmap on top of the native path still matches
yv = jax.jit(jax.vmap(plan.forward))(xd)
assert float(jnp.max(jnp.abs(yv - ref))) / np.abs(ref).max() < 1e-5

# mode="measure" with batch=B builds and times vmapped candidates
res = tuning.tune((N,N,N), mesh, mode="measure", problem="r2c",
                  batch=B, top_k=1, measure_iters=2, measure_warmup=1)
assert res.measured_s is not None and res.measured_s > 0
assert res.key.endswith("|b%d" % B), res.key
t = tuning.time_forward(plan, warmup=1, iters=2, batch=B)
assert t > 0
print("OK batched packed r2c", err, "colls", n1, "measured", res.measured_s)
""", timeout=900)
