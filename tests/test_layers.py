"""Layer-level unit tests: norms, rope, attention core, MoE, recurrences."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import layers as L
from repro.models.attention import (MaskSpec, blockwise_attention, gqa_fwd,
                                    init_gqa, init_mla, mla_fwd)
from repro.models.config import AttentionSpec, MoESpec, RecurrentSpec
from repro.models.moe import init_moe, moe_fwd, aux_load_balance_loss
from repro.models.recurrent import (matrix_recurrence, vector_recurrence,
                                    rglru_fwd, rwkv6_fwd, init_rglru,
                                    init_rwkv6, rglru_init_state,
                                    rwkv6_init_state, RGLRUState, RWKVState)


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------

def test_rmsnorm(rng):
    p = L.init_norm("rmsnorm", 16)
    x = jnp.asarray(rng.randn(2, 3, 16).astype(np.float32))
    y = np.asarray(L.norm_fwd(p, x, "rmsnorm"))
    expected = x / np.sqrt(np.mean(np.asarray(x) ** 2, -1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(y, expected, rtol=1e-4)


def test_layernorm_zero_mean(rng):
    p = L.init_norm("layernorm", 16)
    x = jnp.asarray(rng.randn(2, 3, 16).astype(np.float32) * 5 + 3)
    y = np.asarray(L.norm_fwd(p, x, "layernorm"))
    np.testing.assert_allclose(y.mean(-1), 0, atol=1e-5)
    np.testing.assert_allclose(y.std(-1), 1, atol=1e-2)


def test_rope_preserves_norm_and_relative_positions(rng):
    x = jnp.asarray(rng.randn(1, 8, 2, 16).astype(np.float32))
    pos = jnp.arange(8)
    cos, sin = L.rope_angles(pos, 16, 10_000.0)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.randn(1, 1, 1, 16).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 1, 1, 16).astype(np.float32))
    def dot_at(i, j):
        ci, si = L.rope_angles(jnp.asarray([i]), 16, 10_000.0)
        cj, sj = L.rope_angles(jnp.asarray([j]), 16, 10_000.0)
        return float(jnp.sum(L.apply_rope(q, ci, si) * L.apply_rope(k, cj, sj)))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


# --------------------------------------------------------------------------
# attention core
# --------------------------------------------------------------------------

def _naive_attention(q, k, v, mask):
    s = np.einsum("bqhd,bkhd->bhqk", q, k)
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("kv_block", [4, 8, 32])
def test_blockwise_matches_naive_causal(kv_block, rng):
    b, s, h, d = 2, 32, 4, 8
    q = rng.randn(b, s, h, d).astype(np.float32)
    k = rng.randn(b, s, h, d).astype(np.float32)
    v = rng.randn(b, s, h, d).astype(np.float32)
    pos = jnp.arange(s)
    out = np.asarray(blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        MaskSpec(causal=True), pos, pos, kv_block=kv_block))
    mask = np.tril(np.ones((s, s), bool))
    ref = _naive_attention(q, k, v, mask)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_blockwise_gqa_grouping(rng):
    """4 query heads sharing 2 kv heads == explicit repeat."""
    b, s, h, kvh, d = 1, 16, 4, 2, 8
    q = rng.randn(b, s, h, d).astype(np.float32)
    k = rng.randn(b, s, kvh, d).astype(np.float32)
    v = rng.randn(b, s, kvh, d).astype(np.float32)
    pos = jnp.arange(s)
    out = np.asarray(blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        MaskSpec(causal=True), pos, pos, kv_block=8))
    k_rep = np.repeat(k, h // kvh, axis=2)
    v_rep = np.repeat(v, h // kvh, axis=2)
    # blockwise groups q as (kv, g): q head order is kv-major
    qg = q.reshape(b, s, kvh, h // kvh, d).reshape(b, s, h, d)
    ref = _naive_attention(qg, k_rep, v_rep, np.tril(np.ones((s, s), bool)))
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_sliding_window_mask(rng):
    b, s, h, d = 1, 32, 2, 8
    q = rng.randn(b, s, h, d).astype(np.float32)
    k = rng.randn(b, s, h, d).astype(np.float32)
    v = rng.randn(b, s, h, d).astype(np.float32)
    pos = jnp.arange(s)
    w = 8
    out = np.asarray(blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        MaskSpec(causal=True, window=w), pos, pos, kv_block=8))
    qi, ki = np.arange(s)[:, None], np.arange(s)[None, :]
    mask = (ki <= qi) & (qi - ki < w)
    ref = _naive_attention(q, k, v, mask)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_prefix_lm_mask(rng):
    b, s, h, d = 1, 16, 2, 8
    q = rng.randn(b, s, h, d).astype(np.float32)
    k = rng.randn(b, s, h, d).astype(np.float32)
    v = rng.randn(b, s, h, d).astype(np.float32)
    pos = jnp.arange(s)
    out = np.asarray(blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        MaskSpec(causal=True, prefix_len=6), pos, pos, kv_block=8))
    qi, ki = np.arange(s)[:, None], np.arange(s)[None, :]
    mask = (ki <= qi) | (ki < 6)
    ref = _naive_attention(q, k, v, mask)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_empty_slots_masked(rng):
    """pos == -1 (empty ring-cache slots) must contribute nothing."""
    b, s, h, d = 1, 4, 2, 8
    q = rng.randn(b, 1, h, d).astype(np.float32)
    k = rng.randn(b, s, h, d).astype(np.float32)
    v = rng.randn(b, s, h, d).astype(np.float32)
    k_pos = jnp.asarray([0, 1, -1, -1])
    out = np.asarray(blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        MaskSpec(causal=True), jnp.asarray([5]), k_pos, kv_block=4))
    ref = _naive_attention(q, k[:, :2], v[:, :2], np.ones((1, 2), bool))
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_mla_shapes(rng):
    a = AttentionSpec(kind="mla", n_heads=4, n_kv_heads=4, head_dim=24,
                      q_lora_rank=16, kv_lora_rank=8, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16)
    p = init_mla(jax.random.PRNGKey(0), 32, a)
    x = jnp.asarray(rng.randn(2, 8, 32).astype(np.float32))
    y, latent = mla_fwd(p, x, a, MaskSpec(causal=True), jnp.arange(8))
    assert y.shape == (2, 8, 32)
    assert latent.shape == (2, 8, 8 + 8)  # kv_lora + rope


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------

def test_moe_no_drop_equals_dense_reference(rng):
    d, e, k = 16, 4, 2
    m = MoESpec(n_experts=e, top_k=k, d_ff_expert=32, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), d, m)
    x = jnp.asarray(rng.randn(2, 8, d).astype(np.float32))
    y = np.asarray(moe_fwd(p, x, m))
    # dense reference: run every expert on every token, weight by gates
    xt = np.asarray(x).reshape(-1, d)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, -1)[:, :k]
    ref = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        gs = probs[t, top[t]]
        gs = gs / gs.sum()
        for j, eid in enumerate(top[t]):
            g = np.asarray(jax.nn.silu(xt[t] @ np.asarray(p["w_gate"][eid])))
            u = xt[t] @ np.asarray(p["w_up"][eid])
            ref[t] += gs[j] * (g * u) @ np.asarray(p["w_down"][eid])
    np.testing.assert_allclose(y.reshape(-1, d), ref, atol=1e-4)


def test_moe_capacity_drops_tokens(rng):
    d, e = 8, 2
    m = MoESpec(n_experts=e, top_k=1, d_ff_expert=16, capacity_factor=0.1)
    p = init_moe(jax.random.PRNGKey(1), d, m)
    x = jnp.asarray(rng.randn(4, 64, d).astype(np.float32))
    y = np.asarray(moe_fwd(p, x, m))
    # capacity 0.1 -> most tokens dropped -> many exactly-zero outputs
    zero_rows = np.sum(np.all(y.reshape(-1, d) == 0, axis=-1))
    assert zero_rows > 100


def test_moe_aux_loss(rng):
    d, e = 8, 4
    m = MoESpec(n_experts=e, top_k=2, d_ff_expert=16)
    p = init_moe(jax.random.PRNGKey(2), d, m)
    x = jnp.asarray(rng.randn(2, 32, d).astype(np.float32))
    aux = float(aux_load_balance_loss(p, x, m))
    assert 0.5 < aux < 4.0  # ~1 at balance


# --------------------------------------------------------------------------
# recurrences (vs naive loops)
# --------------------------------------------------------------------------

def test_vector_recurrence_vs_loop(rng):
    B, T, D = 2, 37, 5
    log_a = -np.abs(rng.randn(B, T, D)).astype(np.float32) * 0.3
    b = rng.randn(B, T, D).astype(np.float32)
    h0 = rng.randn(B, D).astype(np.float32)
    h, hl = vector_recurrence(jnp.asarray(log_a), jnp.asarray(b),
                              jnp.asarray(h0), chunk=8)
    href = np.zeros((B, T, D), np.float32)
    hp = h0.copy()
    for t in range(T):
        hp = np.exp(log_a[:, t]) * hp + b[:, t]
        href[:, t] = hp
    np.testing.assert_allclose(np.asarray(h), href, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hl), hp, atol=2e-5)


@pytest.mark.parametrize("chunk", [1, 4, 6, 24])
def test_matrix_recurrence_vs_loop(chunk, rng):
    B, T, H, K, V = 2, 24, 3, 4, 4
    log_w = -np.abs(rng.randn(B, T, H, K)).astype(np.float32) * 0.5
    k = rng.randn(B, T, H, K).astype(np.float32)
    v = rng.randn(B, T, H, V).astype(np.float32)
    r = rng.randn(B, T, H, K).astype(np.float32)
    u = rng.randn(H, K).astype(np.float32)
    s0 = rng.randn(B, H, K, V).astype(np.float32)
    o, sl = matrix_recurrence(*map(jnp.asarray, (log_w, k, v, r)),
                              jnp.asarray(u), jnp.asarray(s0), chunk=chunk)
    oref = np.zeros((B, T, H, V), np.float32)
    s = s0.copy()
    for t in range(T):
        a = np.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        oref[:, t] = np.einsum("bhk,bhkv->bhv", r[:, t],
                               s + u[None, :, :, None] * a)
        s = np.exp(log_w[:, t])[..., None] * s + a
    np.testing.assert_allclose(np.asarray(o), oref, atol=2e-5)
    np.testing.assert_allclose(np.asarray(sl), s, atol=2e-5)


def test_rglru_decode_matches_prefill(rng):
    """Step-by-step decode == one prefill pass over the same tokens."""
    d = 16
    spec = RecurrentSpec(kind="rglru", d_state=d, conv_width=4, chunk=4)
    p = init_rglru(jax.random.PRNGKey(0), d, spec)
    x = jnp.asarray(rng.randn(2, 12, d).astype(np.float32))
    y_all, st_all = rglru_fwd(p, x, spec, rglru_init_state(2, d, 4, jnp.float32))
    st = rglru_init_state(2, d, 4, jnp.float32)
    ys = []
    for t in range(12):
        y, st = rglru_fwd(p, x[:, t:t+1], spec, st)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.concatenate(ys, 1), np.asarray(y_all),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_all.h),
                               atol=3e-5)


def test_rwkv6_decode_matches_prefill(rng):
    d = 16
    spec = RecurrentSpec(kind="rwkv6", n_heads=2, chunk=4)
    p = init_rwkv6(jax.random.PRNGKey(0), d, spec)
    x = jnp.asarray(rng.randn(1, 8, d).astype(np.float32))
    y_all, st_all = rwkv6_fwd(p, x, spec, rwkv6_init_state(1, d, 2, jnp.float32))
    st = rwkv6_init_state(1, d, 2, jnp.float32)
    ys = []
    for t in range(8):
        y, st = rwkv6_fwd(p, x[:, t:t+1], spec, st)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.concatenate(ys, 1), np.asarray(y_all),
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(st.s), np.asarray(st_all.s),
                               atol=3e-4)
