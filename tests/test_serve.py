"""repro.serve: batching policy, plan cache, service correctness.

Single-device tests run the real service (meshless plans compile in
milliseconds at 8^3/16^3); the distributed path — batched dispatch on a
2x4 pencil mesh with cold->warm measurement upgrades and LRU eviction —
runs once in an 8-virtual-device subprocess.
"""

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core import Croft3D
from repro.serve import (Batcher, PlanCache, TransformRequest,
                         TransformService, bucket_key, padded_size,
                         stack_and_pad)
from repro.tuning import wisdom as wisdom_lib
from conftest import run_multidevice

N = 8


def _cplx(rng, n=N):
    return (rng.randn(n, n, n) + 1j * rng.randn(n, n, n)).astype(np.complex64)


# --- batching policy --------------------------------------------------------

def test_padded_size_powers_of_two():
    assert [padded_size(n, 8) for n in (1, 2, 3, 4, 5, 8)] == [1, 2, 4, 4, 8, 8]
    assert padded_size(3, 4) == 4
    with pytest.raises(ValueError):
        padded_size(0, 8)
    with pytest.raises(ValueError):
        padded_size(9, 8)


def test_stack_and_pad_zero_fills():
    rng = np.random.RandomState(0)
    arrays = [_cplx(rng) for _ in range(3)]
    batch = stack_and_pad(arrays, 4)
    assert batch.shape == (4, N, N, N)
    for i, a in enumerate(arrays):
        assert np.array_equal(batch[i], a)
    assert not batch[3].any()


def test_batcher_dispatches_on_full_or_expired():
    b = Batcher(max_batch=2, max_wait_s=10.0)
    rng = np.random.RandomState(0)
    r = lambda: TransformRequest(x=_cplx(rng))
    b.add("k1", r(), now=0.0)
    assert b.pop_ready(now=0.1) == []           # neither full nor expired
    b.add("k1", r(), now=0.2)
    ready = b.pop_ready(now=0.3)                # full
    assert [len(x) for x in ready] == [2] and b.pending == 0
    b.add("k2", r(), now=1.0)
    assert b.pop_ready(now=5.0) == []
    assert len(b.pop_ready(now=11.5)) == 1      # oldest past wait budget
    b.add("k3", r(), now=20.0)
    assert b.next_deadline(now=25.0) == 5.0     # expiry drives poll timeout


# --- request validation and bucketing ---------------------------------------

def test_request_validation():
    rng = np.random.RandomState(0)
    x = _cplx(rng)
    with pytest.raises(ValueError, match="problem"):
        TransformRequest(x=x, problem="dct")
    with pytest.raises(ValueError, match="filter h"):
        TransformRequest(x=x, problem="filtered")
    with pytest.raises(ValueError, match="forward-only"):
        TransformRequest(x=x, problem="filtered", h=x, direction="inverse")
    with pytest.raises(ValueError, match="shape="):
        # Nz is ambiguous from a half spectrum: Nh = Nz//2 + 1 is 2-to-1
        TransformRequest(x=x[:, :, :5], problem="r2c", direction="inverse")
    req = TransformRequest(x=np.abs(x).astype(np.float32), problem="r2c")
    req.validate_payload()
    bad = TransformRequest(x=x, problem="r2c")  # complex payload
    with pytest.raises(ValueError, match="must be real"):
        bad.validate_payload()
    short = TransformRequest(x=x[:, :, :5], problem="c2c")
    with pytest.raises(ValueError, match="payload shape"):
        # declared grid defaults to the payload shape; now contradict it
        short.shape = (N, N, N)
        short.validate_payload()


def test_bucket_key_separates_executables():
    """Direction and filteredness select different executables on the
    same plan — omitting either from the key would alias batches."""
    rng = np.random.RandomState(0)
    x = _cplx(rng)
    fwd = TransformRequest(x=x)
    inv = TransformRequest(x=x, direction="inverse")
    fil = TransformRequest(x=x, problem="filtered", h=x)
    keys = {bucket_key(r, "plan") for r in (fwd, inv, fil)}
    assert len(keys) == 3


# --- plan cache (meshless) --------------------------------------------------

def test_plan_cache_hits_and_lru_eviction():
    cache = PlanCache(max_plans=2)
    a = cache.get((8, 8, 8))
    assert cache.get((8, 8, 8)).plan is a.plan          # hit
    cache.get((16, 16, 16))
    cache.get((8, 8, 8))                                 # A now most recent
    cache.get((8, 8, 12))                                # evicts 16^3 (LRU)
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    assert cache.key_for((16, 16, 16), np.complex64, "c2c") not in cache.keys()
    assert cache.key_for((8, 8, 8), np.complex64, "c2c") in cache.keys()
    # meshless plans are warm from birth: nothing to measure-upgrade
    assert all(cp["state"] == "warm"
               for cp in cache.snapshot()["plans"].values())


def test_plan_cache_over_capacity_does_not_livelock():
    """When every other plan is pinned by an in-flight upgrade, eviction
    must bail (temporary over-capacity) instead of spinning on the lock
    the upgrade threads need to finish."""
    cache = PlanCache(max_plans=2)
    cache.get((8, 8, 8))
    cache.get((16, 16, 16))
    for cp in cache._plans.values():
        cp.upgrading = True  # simulate in-flight measurement upgrades
    done = []

    def miss():
        cache.get((8, 8, 12))  # pre-fix: spins forever in eviction
        done.append(True)

    t = threading.Thread(target=miss, daemon=True)
    t.start()
    t.join(timeout=30.0)
    assert done, "plan-cache eviction livelocked with all plans upgrading"
    assert len(cache) == 3  # over capacity until upgrades land
    for cp in cache._plans.values():
        cp.upgrading = False
    cache.get((8, 8, 16))  # next miss drains the excess
    assert len(cache) == 2


def test_plan_cache_key_separates_problems_and_dtypes():
    cache = PlanCache()
    keys = {cache.key_for((8, 8, 8), np.complex64, "c2c"),
            cache.key_for((8, 8, 8), np.complex64, "r2c"),
            cache.key_for((8, 8, 8), np.complex128, "c2c"),
            cache.key_for((8, 8, 16), np.complex64, "c2c")}
    assert len(keys) == 4


# --- service correctness (single device) ------------------------------------

def test_service_concurrent_heterogeneous_bitwise():
    """Interleaved c2c/r2c/filtered requests from concurrent clients each
    come back bitwise-equal to the direct Croft3D call."""
    rng = np.random.RandomState(0)
    xc, h = _cplx(rng), _cplx(rng)
    xr = rng.randn(N, N, N).astype(np.float32)
    plan_c = Croft3D((N, N, N))
    plan_r = Croft3D((N, N, N), problem="r2c")
    spec_c = np.asarray(plan_c.forward(xc))
    spec_r = np.asarray(plan_r.forward(xr))
    want = {
        "c2c-fwd": (dict(problem="c2c"), xc, spec_c),
        "c2c-inv": (dict(problem="c2c", direction="inverse"), spec_c,
                    np.asarray(plan_c.inverse(spec_c))),
        "r2c-fwd": (dict(problem="r2c"), xr, spec_r),
        "r2c-inv": (dict(problem="r2c", direction="inverse",
                         shape=(N, N, N)), spec_r,
                    np.asarray(plan_r.inverse(spec_r))),
        "filtered": (dict(problem="filtered", h=h), xc,
                     np.asarray(plan_c.forward_filtered(xc, h))),
    }
    failures = []

    def client(name, reps=3):
        kw, x, ref = want[name]
        for _ in range(reps):
            got = svc.transform(x, **kw)
            if not np.array_equal(got, ref):
                failures.append((name, float(np.max(np.abs(got - ref)))))

    with TransformService(max_batch=4, max_wait_ms=2.0) as svc:
        threads = [threading.Thread(target=client, args=(name,))
                   for name in want for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = svc.stats()
    assert not failures, failures
    assert stats["requests"] == 2 * 3 * len(want)
    assert stats["pending"] == 0


def test_service_ragged_batch_pads_and_round_trips():
    """3 same-key requests coalesce into one dispatch padded to 4; the
    pad row never leaks into results."""
    rng = np.random.RandomState(1)
    xs = [_cplx(rng) for _ in range(3)]
    plan = Croft3D((N, N, N))
    with TransformService(max_batch=4, max_wait_ms=100.0) as svc:
        futs = [svc.submit(x) for x in xs]
        results = [f.result(timeout=120) for f in futs]
    assert all(r.ok for r in results)
    for x, r in zip(xs, results):
        assert np.array_equal(r.value, np.asarray(plan.forward(x)))
    assert {r.batch_size for r in results} == {3}
    assert {r.padded_size for r in results} == {4}


def test_service_stop_drains_pending():
    rng = np.random.RandomState(2)
    svc = TransformService(max_batch=8, max_wait_ms=5000.0)
    svc.start()
    futs = [svc.submit(_cplx(rng)) for _ in range(3)]
    svc.stop(drain=True)  # wait budget far away: stop must still serve
    assert all(f.result(timeout=60).ok for f in futs)
    with pytest.raises(RuntimeError, match="not started"):
        svc.submit(_cplx(rng))


def test_service_drain_chunks_oversized_buckets():
    """stop(drain=True) can inherit a same-key bucket larger than
    max_batch (leftover partial bucket plus late arrivals); it must chunk
    into max_batch-sized dispatches and serve every request, not fail
    them with a padded_size error."""
    import concurrent.futures
    from repro.serve.service import _Pending
    rng = np.random.RandomState(4)
    xs = [_cplx(rng) for _ in range(5)]
    ref = [np.asarray(Croft3D((N, N, N)).forward(x)) for x in xs]
    svc = TransformService(max_batch=2, max_wait_ms=5000.0)
    pendings = []
    for x in xs:  # straight to the queue, as if racing past the sentinel
        req = TransformRequest(x=x)
        req.validate_payload()
        pendings.append(_Pending(req, concurrent.futures.Future()))
        svc._queue.put(pendings[-1])
    svc._drain_all()
    results = [p.future.result(timeout=60) for p in pendings]
    assert all(r.ok for r in results), [r.error for r in results]
    assert all(r.padded_size <= 2 for r in results)
    for r, want in zip(results, ref):
        assert np.array_equal(r.value, want)


def test_service_rejects_malformed_at_submit():
    with TransformService() as svc:
        with pytest.raises(ValueError, match="rank-3"):
            svc.submit(np.zeros((4, 4), np.complex64))
        # a malformed request must not have poisoned the worker
        rng = np.random.RandomState(3)
        x = _cplx(rng)
        assert np.array_equal(svc.transform(x),
                              np.asarray(Croft3D((N, N, N)).forward(x)))


# --- wisdom: concurrent merge + stats CLI -----------------------------------

def _entry(created=None, measured=None, problem="c2c"):
    from repro.tuning.candidates import default_candidate
    cand = default_candidate((8, 8, 8), {"y": 2, "z": 2}, problem=problem)
    e = wisdom_lib.WisdomEntry.from_candidate(
        cand, source="measure" if measured else "model",
        model_s=1e-3, measured_s=measured)
    if created is not None:
        e.created = created
    return e


def test_wisdom_merge_entries_concurrent_writers(tmp_path):
    """16 threads merging disjoint keys into one file must not lose
    updates (the reload-under-lock + atomic-rename discipline)."""
    path = str(tmp_path / "w.json")
    errs = []

    def writer(i):
        try:
            wisdom_lib.merge_entries(path, {f"key{i}": _entry()})
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    w = wisdom_lib.Wisdom.load(path)
    assert sorted(w.entries) == sorted(f"key{i}" for i in range(16))
    assert not os.path.exists(path + ".lock")  # lock released


def test_wisdom_merge_entries_keeps_better(tmp_path):
    path = str(tmp_path / "w.json")
    wisdom_lib.merge_entries(path, {"k": _entry(measured=2e-3)})
    wisdom_lib.merge_entries(path, {"k": _entry(measured=5e-3)})  # slower
    wisdom_lib.merge_entries(path, {"k": _entry()})               # unmeasured
    w = wisdom_lib.Wisdom.load(path)
    assert w.entries["k"].measured_s == 2e-3


def test_wisdom_stale_lock_is_broken(tmp_path):
    path = str(tmp_path / "w.json")
    lock = path + ".lock"
    with open(lock, "w") as f:
        f.write("999999")
    old = time.time() - 60.0
    os.utime(lock, (old, old))  # a writer that died a minute ago
    with wisdom_lib._FileLock(lock, timeout=1.0, stale_s=30.0):
        pass  # acquired by breaking the stale lock, not by timeout


def test_wisdom_fresh_lock_survives_break_attempt(tmp_path):
    """_break_stale must not unlink a live writer's fresh lock (the
    two-waiters-both-observe-stale race): a fresh lock is restored, a
    genuinely stale one is removed."""
    lock = str(tmp_path / "w.json.lock")
    fl = wisdom_lib._FileLock(lock, timeout=1.0, stale_s=30.0)
    with open(lock, "w") as f:
        f.write("123")  # a live holder's fresh lock
    fl._break_stale()
    assert os.path.exists(lock), "fresh lock was stolen"
    old = time.time() - 60.0
    os.utime(lock, (old, old))  # now it really is a dead writer's
    fl._break_stale()
    assert not os.path.exists(lock)
    assert not any(p.name.startswith("w.json.lock.stale")
                   for p in tmp_path.iterdir())  # no litter


def test_wisdom_stats_cli(tmp_path, capsys):
    path = str(tmp_path / "w.json")
    wisdom_lib.merge_entries(path, {
        "8x8x8|y=2,z=2|complex64|cpu": _entry(created=time.time() - 3600),
        "8x8x8|y=2,z=2|complex64|cpu|r2c": _entry(measured=1e-3,
                                                  problem="r2c"),
    })
    assert wisdom_lib._main(["stats", path]) == 0
    out = capsys.readouterr().out
    assert "2 entries" in out
    assert "measure=1" in out and "model=1" in out
    assert "c2c=1" in out and "r2c=1" in out
    assert "staleness:" in out and "1.0h old" in out


def test_wisdom_merge_cli_folds_files(tmp_path, capsys):
    a, b, out = (str(tmp_path / n) for n in ("a.json", "b.json", "out.json"))
    wisdom_lib.merge_entries(a, {"ka": _entry()})
    wisdom_lib.merge_entries(b, {"kb": _entry()})
    assert wisdom_lib._main(["merge", out, a, b]) == 0
    assert sorted(wisdom_lib.Wisdom.load(out).entries) == ["ka", "kb"]


# --- distributed service: one subprocess, the full lifecycle ----------------

_MULTIDEVICE_CODE = """
import json, os, tempfile, time
import numpy as np, jax
from repro.serve import TransformService, PlanCache

mesh = jax.make_mesh((2, 4), ("y", "z"))
wisdom = os.path.join(tempfile.mkdtemp(), "w.json")
cache = PlanCache(mesh, wisdom_path=wisdom, max_plans=2, measure_after=3,
                  upgrade_async=False, tune_kw=dict(top_k=2, measure_iters=1))
svc = TransformService(mesh, max_batch=4, max_wait_ms=30.0, cache=cache)
rng = np.random.RandomState(0)
N = 16
xc = (rng.randn(N, N, N) + 1j * rng.randn(N, N, N)).astype(np.complex64)
xr = rng.randn(N, N, N).astype(np.float32)

with svc:
    # heterogeneous concurrent batch: 3 c2c (ragged -> padded 4) + 1 r2c
    futs = [svc.submit(xc) for _ in range(3)] + [svc.submit(xr, problem="r2c")]
    results = [f.result(timeout=400) for f in futs]
    assert all(r.ok for r in results), [r.error for r in results]
    assert results[0].batch_size == 3 and results[0].padded_size == 4

    # bitwise equality against direct calls on the same cached plans
    plan_c = cache.get((N, N, N), np.complex64, "c2c").plan
    ref = np.asarray(plan_c.forward(
        jax.device_put(xc, plan_c.input_sharding)))
    for r in results[:3]:
        assert np.array_equal(r.value, ref)
    plan_r = cache.get((N, N, N), np.complex64, "r2c").plan
    ref_r = np.asarray(plan_r.forward(jax.device_put(
        xr.astype(plan_r.input_dtype), plan_r.input_sharding)))
    assert np.array_equal(results[3].value, ref_r)

    # cold -> warm: measure_after=3 dispatches arms the (synchronous
    # here) measurement upgrade; later dispatches ride the measured plan
    states = [svc.submit(xc).result(timeout=400).plan_state
              for _ in range(3)]
    assert states[-1] == "warm", states
    assert cache.stats.upgrades == 1

    # the measured winner was merged into the wisdom store atomically
    blob = json.load(open(wisdom))
    measured = [k for k, e in blob["entries"].items()
                if e["source"] == "measure"]
    assert measured, blob["entries"].keys()
    assert not os.path.exists(wisdom + ".lock")

    # LRU eviction under shape diversity: a third key exceeds max_plans=2
    assert svc.submit((rng.randn(8, 8, 8) + 0j).astype(np.complex64)
                      ).result(timeout=400).ok
    assert len(cache) == 2 and cache.stats.evictions >= 1

print("SERVE_MULTIDEVICE_OK")
"""


def test_service_multidevice_lifecycle():
    out = run_multidevice(_MULTIDEVICE_CODE, n_devices=8, timeout=480)
    assert "SERVE_MULTIDEVICE_OK" in out
