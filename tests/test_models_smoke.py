"""Per-arch reduced-config smoke tests: one forward + one train step on CPU,
asserting output shapes and finiteness (assignment requirement), plus
prefill/decode agreement with the teacher-forced pass.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ASSIGNED, get_config
from repro.models import encode, forward, init_caches, init_params
from repro.models.config import Stage


def _inputs(cfg, key, B, S):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kwargs = {}
    if cfg.encoder is not None:
        frames = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
        kwargs["frames"] = frames
    elif cfg.frontend == "vision":
        kwargs["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
    return tokens, kwargs


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 32
    tokens, kwargs = _inputs(cfg, key, B, S)
    fwd_kwargs = {}
    if "frames" in kwargs:
        fwd_kwargs["enc_out"] = encode(params, cfg, kwargs["frames"])
    elif "prefix_embeds" in kwargs:
        fwd_kwargs["prefix_embeds"] = kwargs["prefix_embeds"]
    logits, _ = forward(params, cfg, tokens, mode="train", kv_block=16,
                        **fwd_kwargs)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x22b", "rwkv6-3b"])
def test_train_step_reduces_loss(arch):
    from repro.train import OptConfig, init_train_state, make_train_step
    from repro.train.data import SyntheticDataset
    cfg = get_config(arch, smoke=True)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, decay_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, mesh=None)
    step_fn = make_train_step(cfg, opt_cfg, None, 4, kv_block=32,
                              n_loss_chunks=4)
    ds = SyntheticDataset(cfg.vocab, 64, 4)
    # warmup_steps=2 leaves the first two steps nearly lr-free: run long
    # enough that at least three post-warmup updates shape the trend
    losses = []
    for _, batch in zip(range(5), ds):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def _high_capacity(cfg):
    """Crank MoE capacity so drops don't break decode-vs-teacher equality."""
    stages = []
    for st in cfg.stages:
        pat = tuple(
            dataclasses.replace(sp, moe=dataclasses.replace(
                sp.moe, capacity_factor=16.0)) if sp.moe else sp
            for sp in st.pattern)
        stages.append(Stage(pat, st.repeat))
    return dataclasses.replace(cfg, stages=tuple(stages))


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-236b", "gemma3-4b",
                                  "recurrentgemma-9b", "rwkv6-3b",
                                  "whisper-base", "paligemma-3b",
                                  "mixtral-8x22b", "h2o-danube-3-4b",
                                  "yi-34b"])
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(_high_capacity(cfg), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 24
    tokens, kwargs = _inputs(cfg, key, B, S + 1)
    fwd_kwargs = {}
    enc_len = 0
    if "frames" in kwargs:
        fwd_kwargs["enc_out"] = encode(params, cfg, kwargs["frames"])
        enc_len = cfg.n_frontend_tokens
    elif "prefix_embeds" in kwargs:
        fwd_kwargs["prefix_embeds"] = kwargs["prefix_embeds"]
    ref, _ = forward(params, cfg, tokens, mode="train", kv_block=16,
                     **fwd_kwargs)
    caches = init_caches(cfg, B, max_len=64, enc_len=enc_len,
                         dtype=jnp.float32)
    pre, caches = forward(params, cfg, tokens[:, :S], mode="prefill",
                          caches=caches, kv_block=16, **fwd_kwargs)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(ref[:, :S]),
                               rtol=0, atol=2e-4 * np.abs(np.asarray(ref)).max())
    dec_kwargs = {k: v for k, v in fwd_kwargs.items() if k != "prefix_embeds"}
    start = S + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    dec, _ = forward(params, cfg, tokens[:, S:S + 1], mode="decode",
                     caches=caches, start=start, kv_block=16, **dec_kwargs)
    np.testing.assert_allclose(
        np.asarray(dec[:, 0]), np.asarray(ref[:, S]),
        rtol=0, atol=2e-4 * np.abs(np.asarray(ref)).max())


def test_sliding_window_ring_cache_long_decode():
    """Decode past the window: ring cache must equal a fresh full pass."""
    cfg = get_config("h2o-danube-3-4b", smoke=True)  # window 32 smoke
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 48  # past the 32-token window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab)
    ref, _ = forward(params, cfg, tokens, mode="train", kv_block=16)
    caches = init_caches(cfg, B, max_len=64, dtype=jnp.float32)
    _, caches = forward(params, cfg, tokens[:, :S], mode="prefill",
                        caches=caches, kv_block=16)
    dec, _ = forward(params, cfg, tokens[:, S:S + 1], mode="decode",
                     caches=caches, start=S, kv_block=16)
    np.testing.assert_allclose(
        np.asarray(dec[:, 0]), np.asarray(ref[:, S]),
        atol=2e-4 * np.abs(np.asarray(ref)).max())


def test_param_counts_match_public():
    expected = {
        "mixtral-8x22b": (141e9, 0.02), "deepseek-v2-236b": (236e9, 0.02),
        "yi-34b": (34.4e9, 0.02), "yi-9b": (8.8e9, 0.02),
        "rwkv6-3b": (3.1e9, 0.05), "h2o-danube-3-4b": (4.0e9, 0.05),
    }
    for arch, (target, tol) in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n)


def test_active_params_moe():
    assert get_config("mixtral-8x22b").active_param_count() < 45e9
    assert get_config("deepseek-v2-236b").active_param_count() < 25e9
