"""Distribution substrate on virtual multi-device meshes (subprocesses)."""

import pytest

from conftest import run_multidevice


def test_param_sharding_rules():
    run_multidevice("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.models import init_params
from repro.parallel import sharding as sh
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
axes = sh.MeshAxes()
for arch in ["yi-9b", "mixtral-8x22b", "deepseek-v2-236b", "gemma3-4b", "rwkv6-3b", "recurrentgemma-9b"]:
    cfg = get_config(arch, smoke=True)
    abstract = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    specs = sh.param_specs(abstract, mesh, axes)
    flat_a, _ = jax.tree_util.tree_flatten_with_path(abstract)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = 0
    for (path, leaf), spec in zip(flat_a, flat_s):
        # every spec must divide
        for dim, entry in zip(leaf.shape, spec):
            if entry is None: continue
            sz = np.prod([mesh.shape[a] for a in (entry if isinstance(entry, tuple) else (entry,))])
            assert dim % sz == 0, (arch, path, leaf.shape, spec)
        if any(e is not None for e in spec):
            n_sharded += 1
    assert n_sharded > len(flat_a) * 0.5, (arch, n_sharded, len(flat_a))
    print("OK", arch, f"{n_sharded}/{len(flat_a)} sharded")
""")


def test_cp_recurrences_match_local():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.seqscan import cp_vector_recurrence, cp_matrix_recurrence
from repro.models.recurrent import vector_recurrence, matrix_recurrence
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.RandomState(0)
B,T,D = 4, 64, 16
log_a = -np.abs(rng.randn(B,T,D)).astype(np.float32)*0.3
b = rng.randn(B,T,D).astype(np.float32); h0 = rng.randn(B,D).astype(np.float32)
ref, ref_l = vector_recurrence(*map(jnp.asarray,(log_a,b)), jnp.asarray(h0), 16)
h, hl = cp_vector_recurrence(jnp.asarray(log_a), jnp.asarray(b), jnp.asarray(h0),
                             mesh=mesh, cp_axis="model", batch_spec="data", chunk=4)
assert np.max(np.abs(np.asarray(h)-np.asarray(ref))) < 1e-5
assert np.max(np.abs(np.asarray(hl)-np.asarray(ref_l))) < 1e-5
H,K,V = 2, 4, 4
log_w = -np.abs(rng.randn(B,T,H,K)).astype(np.float32)*0.4
k = rng.randn(B,T,H,K).astype(np.float32); v = rng.randn(B,T,H,V).astype(np.float32)
r = rng.randn(B,T,H,K).astype(np.float32); u = rng.randn(H,K).astype(np.float32)
s0 = rng.randn(B,H,K,V).astype(np.float32)
oref, sref = matrix_recurrence(*map(jnp.asarray,(log_w,k,v,r)), jnp.asarray(u), jnp.asarray(s0), 16)
o, sl = cp_matrix_recurrence(*map(jnp.asarray,(log_w,k,v,r)), jnp.asarray(u), jnp.asarray(s0),
                             mesh=mesh, cp_axis="model", batch_spec="data", chunk=4)
assert np.max(np.abs(np.asarray(o)-np.asarray(oref))) < 1e-4
assert np.max(np.abs(np.asarray(sl)-np.asarray(sref))) < 1e-4
print("OK cp recurrences")
""")


def test_sharded_train_matches_single_device():
    """The distribution is semantics-preserving: same losses on 1 vs 8 dev."""
    run_multidevice("""
import numpy as np, jax, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train.data import SyntheticDataset
cfg = dataclasses.replace(get_config("yi-9b", smoke=True), dtype="float32")
ocfg = OptConfig(lr=1e-3, warmup_steps=1, decay_steps=8)

# single-device reference
state = init_train_state(jax.random.PRNGKey(0), cfg, ocfg, None)
step1 = make_train_step(cfg, ocfg, None, 8, kv_block=32, donate=False)
ds = SyntheticDataset(cfg.vocab, 32, 8)
ref = []
for i in range(2):
    state, m = step1(state, ds.batch_at(i))
    ref.append(float(m["loss"]))

mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
state2 = init_train_state(jax.random.PRNGKey(0), cfg, ocfg, mesh)
step8 = make_train_step(cfg, ocfg, mesh, 8, kv_block=32, donate=False)
ds2 = SyntheticDataset(cfg.vocab, 32, 8, sharding={"tokens": NamedSharding(mesh, P("data", None))})
got = []
with jax.set_mesh(mesh):
    for i in range(2):
        state2, m = step8(state2, ds2.batch_at(i))
        got.append(float(m["loss"]))
print("ref:", ref, "sharded:", got)
assert np.allclose(ref, got, rtol=2e-4), (ref, got)
""", timeout=600)


def test_compressed_psum_cross_pod():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.parallel.compression import compressed_psum
mesh = jax.make_mesh((4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
g = rng.randn(4, 64).astype(np.float32)  # per-pod gradients

def body(g_loc):
    tree = {"g": g_loc[0]}
    out, res = compressed_psum(tree, "pod")
    return out["g"], res["g"]

out, res = shard_map(body, mesh=mesh, in_specs=P("pod", None),
                     out_specs=(P(), P("pod")))(g)
exact = g.sum(0)
err = np.abs(np.asarray(out) - exact)
amax = np.abs(g).max()
assert err.max() <= 4 * amax / 127 + 1e-5, err.max()
# error feedback bookkeeping: residual equals quantization error
print("OK compressed psum, max err", float(err.max()))
""", n_devices=4)


def test_spectral_mixer_distributed():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.models.spectral import spectral_mixer
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.RandomState(0)
x = rng.randn(4, 32, 64).astype(np.float32)
ref = np.asarray(spectral_mixer(jnp.asarray(x)))
got = np.asarray(spectral_mixer(jnp.asarray(x), seq_axis_name="model",
                                mesh=mesh, batch_spec="data"))
assert np.max(np.abs(ref - got)) < 2e-4, np.max(np.abs(ref-got))
print("OK distributed spectral mixer")
""")


def test_decode_cache_stays_sharded():
    """Flash-decoding contract: decoding must NOT all-gather the KV cache."""
    run_multidevice("""
import jax, jax.numpy as jnp, re
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import model as model_lib
from repro.parallel import sharding as sh
from repro.train import train_step as ts
import dataclasses
cfg = get_config("yi-9b", smoke=True)
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
axes = sh.MeshAxes()
B, S = 8, 256
abstract_params = jax.eval_shape(lambda k: model_lib.init_params(k, cfg), jax.random.key(0))
pspecs = sh.param_specs(abstract_params, mesh, axes)
sds = lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=NamedSharding(mesh, s))
params_sds = jax.tree.map(sds, abstract_params, pspecs, is_leaf=lambda x: isinstance(x, P))
abstract_caches = jax.eval_shape(lambda: model_lib.init_caches(cfg, B, S, dtype=jnp.bfloat16))
cspecs = sh.cache_specs(abstract_caches, mesh, axes)
caches_sds = jax.tree.map(sds, abstract_caches, cspecs, is_leaf=lambda x: isinstance(x, P))
prefill_fn, decode_fn = ts.make_serve_steps(cfg, mesh, B, S, kv_block=64)
tok = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=NamedSharding(mesh, P("data", None)))
with jax.set_mesh(mesh):
    txt = decode_fn.lower(params_sds, tok, caches_sds, 100).compile().as_text()
# KV caches are (B, 256-slot, kv, hd) bf16 sharded over model: a gather of a
# full cache would materialize bf16[8,256,2,16]; assert no all-gather output
# that large exists
import re
ags = re.findall(r"all-gather[^\\n]*", txt)
big = [a for a in ags if "256" in a.split("all-gather")[0]]
assert not big, big[:2]
print("OK decode keeps cache sharded;", len(ags), "small gathers")
""")


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Fault-tolerance contract: a checkpoint written on a (2,4) mesh
    restores onto a (4,2) mesh (node-loss re-shaping) with identical
    values — checkpoints store logical shapes only."""
    import os
    run_multidevice(f"""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import init_params
from repro.parallel import sharding as sh
from repro.train.checkpoint import CheckpointManager
cfg = get_config("yi-9b", smoke=True)
axes = sh.MeshAxes()
mesh_a = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
params = init_params(jax.random.PRNGKey(0), cfg)
sh_a = sh.param_shardings(params, mesh_a, axes)
params_a = jax.tree.map(jax.device_put, params, sh_a)
mgr = CheckpointManager({str(tmp_path)!r}, async_write=False)
mgr.save(7, params_a)
# "lose half the nodes": restore onto a reshaped mesh
mesh_b = jax.make_mesh((4,2), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
sh_b = sh.param_shardings(params, mesh_b, axes)
restored = mgr.restore(params, shardings=sh_b)
flat_o = jax.tree.leaves(params)
flat_r = jax.tree.leaves(restored)
for o, r in zip(flat_o, flat_r):
    np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
print("OK elastic restore across meshes,", len(flat_r), "tensors")
""")
