"""Roofline machinery: trip-count-aware HLO cost analysis + term math."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost
from repro.launch.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                   RooflineTerms, collective_stats)


def test_scan_trip_count_multiplied():
    """XLA's cost_analysis counts a scan body once; ours multiplies."""
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    @jax.jit
    def scanned(x):
        def body(c, _):
            return c @ x, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    compiled = scanned.lower(w).compile()
    from repro import compat
    xla_flops = compat.cost_analysis(compiled)["flops"]
    ours = hlo_cost.analyze(compiled.as_text())
    expect = 10 * 2 * 256 ** 3
    assert abs(ours.flops - expect) / expect < 0.02
    assert xla_flops < expect / 5  # documents the XLA undercount


def test_nested_scan():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    @jax.jit
    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    ours = hlo_cost.analyze(nested.lower(w).compile().as_text())
    expect = 15 * 2 * 128 ** 3
    assert abs(ours.flops - expect) / expect < 0.02


def test_unrolled_matches_xla():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    @jax.jit
    def unrolled(x):
        y = x
        for _ in range(4):
            y = y @ x
        return y

    compiled = unrolled.lower(w).compile()
    ours = hlo_cost.analyze(compiled.as_text())
    from repro import compat
    assert abs(ours.flops - compat.cost_analysis(compiled)["flops"]) \
        / ours.flops < 0.02


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)

    @jax.jit
    def bmm(x, y):
        return jnp.einsum("bik,bkj->bij", x, y)

    ours = hlo_cost.analyze(bmm.lower(a, b).compile().as_text())
    expect = 2 * 4 * 64 * 32 * 16
    assert abs(ours.flops - expect) / expect < 0.02


def test_roofline_terms_math():
    t = RooflineTerms(flops_per_device=197e12, bytes_per_device=819e9,
                      collective_bytes_per_device=50e9, n_devices=4,
                      model_flops=4 * 197e12 * 0.5)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert t.step_time_s == 1.0
    assert abs(t.mfu - 0.5) < 1e-9
    assert t.bottleneck in ("compute", "memory", "collective")


def test_collective_shape_parse():
    txt = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[64]{0} all-reduce(%y), to_apply=%add
  %a2a = c64[4,4]{1,0} all-to-all(%z)
"""
    stats = collective_stats(txt)
    assert stats["all-gather"]["bytes"] == 8 * 128 * 2
    assert stats["all-reduce"]["bytes"] == 64 * 4 * 2  # doubled
    assert stats["all-to-all"]["bytes"] == 16 * 8


def test_cost_analysis_is_per_partition():
    """Foundation of the roofline formulas (DESIGN.md §8)."""
    import os
    from conftest import run_multidevice
    run_multidevice("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
sh = NamedSharding(mesh, P("x", None))
@jax.jit
def f(a):
    return a @ a.T
ca = compat.cost_analysis(f.lower(jax.ShapeDtypeStruct((512, 512), jnp.float32, sharding=sh)).compile())
full = 2 * 512**3
# per-partition: roughly full/8 (plus collective overhead terms)
assert ca["flops"] < full / 4, ca["flops"]
print("OK per-partition flops:", ca["flops"], "vs full", full)
""")


def test_fft_collective_bytes_match_analytic_model():
    """Dry-run collective bytes == the paper's transpose-volume model."""
    from conftest import run_multidevice
    run_multidevice("""
import jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.launch import hlo_cost
mesh = jax.make_mesh((2,4), ("y","z"), axis_types=(jax.sharding.AxisType.Auto,)*2)
plan = Croft3D((32,32,32), mesh, Decomposition("pencil", ("y","z")), FFTOptions())
cost = hlo_cost.analyze(plan.lower_forward().compile().as_text())
assert abs(cost.collective_bytes - plan.comm_bytes_model()) / plan.comm_bytes_model() < 0.05, (
    cost.collective_bytes, plan.comm_bytes_model())
print("OK collective bytes", cost.collective_bytes, "model", plan.comm_bytes_model())
""")
