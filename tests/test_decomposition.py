"""Decomposition descriptors: the paper's scaling limits and shape math."""

import numpy as np
import pytest

from repro.core.decomposition import Decomposition, pencil_grid_for


class FakeMesh:
    """Just enough of a Mesh for the pure shape math."""
    def __init__(self, **shape):
        self.shape = shape


def test_slab_scaling_wall():
    """Paper §2.2.1/§3.1: slab P_max = Nz — the FFTW3 wall of table 1."""
    d = Decomposition("slab", ("z",))
    mesh = FakeMesh(z=256)
    with pytest.raises(ValueError, match="slab decomposition limited"):
        d.validate((128, 128, 128), mesh)


def test_pencil_scaling():
    """Pencil P_max = Ny*Nz (paper §2.2.2): 256 procs on a 128^3 grid is
    fine where slab fails."""
    d = Decomposition("pencil", ("y", "z"))
    mesh = FakeMesh(y=16, z=16)
    d.validate((128, 128, 128), mesh, overlap_k=2)
    assert d.local_shape((128, 128, 128), mesh) == (128, 8, 8)


def test_pencil_divisibility_errors():
    d = Decomposition("pencil", ("y", "z"))
    with pytest.raises(ValueError, match="not divisible"):
        d.validate((128, 100, 128), FakeMesh(y=16, z=16))


def test_cell_local_shape():
    d = Decomposition("cell", ("x", "y", "z"))
    mesh = FakeMesh(x=2, y=2, z=2)
    assert d.local_shape((64, 64, 64), mesh) == (32, 32, 32)


def test_folded_axis_sizes():
    d = Decomposition("pencil", (("pod", "data"), "model"))
    mesh = FakeMesh(pod=2, data=16, model=16)
    assert d.axis_sizes(mesh) == (32, 16)
    assert d.n_procs(mesh) == 512


def test_partition_specs():
    d = Decomposition("pencil", ("y", "z"))
    assert tuple(d.partition_spec()) == (None, "y", "z")
    assert tuple(d.spectral_spec()) == ("y", "z", None)
    s = Decomposition("slab", ("z",))
    assert tuple(s.partition_spec()) == (None, None, "z")


def test_kind_validation():
    with pytest.raises(ValueError):
        Decomposition("pencil", ("y",))
    with pytest.raises(ValueError):
        Decomposition("blob", ("y",))


def test_pencil_grid_for():
    py, pz = pencil_grid_for(256, 1024, 1024)
    assert py * pz == 256 and 1024 % py == 0 and 1024 % pz == 0
    assert py == pz == 16  # near-square preferred (paper fig. 5)
    py, pz = pencil_grid_for(8, 128, 128)
    assert py * pz == 8
    with pytest.raises(ValueError):
        pencil_grid_for(7, 16, 16)  # 7 doesn't divide any pow-2 grid
