"""Schedule-space search: candidate tokens, enumerator pruning, dedup,
per-stage cost dispatch, adjoint/inverse of searched pipelines, wisdom
round trips, and multi-device numerics of schedules no fixed builder
can produce.

Golden ``sched-*`` snapshots pin the searched stage structure (including
the ``impl=``/``K=`` per-stage override rendering) the same way
``test_schedule.py`` pins the fixed builders' output.
"""

import dataclasses
import json

import jax.numpy as jnp
import pytest

from conftest import run_multidevice
from repro.core import Decomposition, FFTOptions
from repro.core import schedule as schedule_lib
from repro.core.distributed import build_schedule
from repro.grad import adjoint_schedule
from repro.tuning import candidates as cand_lib
from repro.tuning import cost_model, planner, wisdom as wisdom_lib
from repro.tuning.candidates import ScheduleCandidate, StageSpec

SIZES = {"data": 2, "model": 4}
PENCIL = Decomposition("pencil", ("data", "model"))

# the gate-A shape: z so short that stage 0's chunk axis cannot split,
# which is what makes mixed per-stage impls win (see benchmarks/
# search_bench.py)
GATE_SHAPE = (512, 512, 4)

MIXED_KEY = ("sched:pencil[data,model]|k1/matmul/spectral/alltoall/"
             "pipelined|f0.t0s0c1h2r;f1.t1s1c2h0k2;f2")
FUSED_KEY = ("sched:pencil[data,model]|k1/matmul/natural/alltoall/"
             "pipelined|f0.t0s0c1h2;f1.t1s1c2h0;f2.t1s2c1h0;t0s1c0h2")
SPLIT_KEY = ("sched:slab[data+model]|k1/matmul/spectral/alltoall/"
             "pipelined|f0;f1;t0s0c2h1;f2")

GOLDEN = {
    "sched-mixed-impls": (MIXED_KEY, """\
schedule sched/pencil[data,model] sign=-1
  in : C(Nx, Ny/data, Nz/model)
  0 x-fft+xy: fft[x]@s0 | a2a[data] split=0 concat=1 chunk=2 impl=ring -> C(Nx/data, Ny, Nz/model)
  1 y-fft+yz: fft[y]@s1 | a2a[model] split=1 concat=2 chunk=0 K=2 -> C(Nx/data, Ny/model, Nz)
  2 z-fft: fft[z]@s2 -> C(Nx/data, Ny/model, Nz)
  out: C(Nx/data, Ny/model, Nz)"""),
    "sched-fused-natural": (FUSED_KEY, """\
schedule sched/pencil[data,model] sign=-1
  in : C(Nx, Ny/data, Nz/model)
  0 x-fft+xy: fft[x]@s0 | a2a[data] split=0 concat=1 chunk=2 -> C(Nx/data, Ny, Nz/model)
  1 y-fft+yz: fft[y]@s1 | a2a[model] split=1 concat=2 chunk=0 -> C(Nx/data, Ny/model, Nz)
  2 z-fft+zy: fft[z]@s2 | a2a[model] split=2 concat=1 chunk=0 -> C(Nx/data, Ny, Nz/model)
  3 move-yx: a2a[data] split=1 concat=0 chunk=2 -> C(Nx, Ny/data, Nz/model)
  out: C(Nx, Ny/data, Nz/model)"""),
    "sched-split-slab": (SPLIT_KEY, """\
schedule sched/slab[data+model] sign=-1
  in : C(Nx, Ny, Nz/data/model)
  0 x-fft: fft[x]@s0 -> C(Nx, Ny, Nz/data/model)
  1 y-fft: fft[y]@s1 -> C(Nx, Ny, Nz/data/model)
  2 move-xz: a2a[data+model] split=0 concat=2 chunk=1 -> C(Nx/data/model, Ny, Nz)
  3 z-fft: fft[z]@s2 -> C(Nx/data/model, Ny, Nz)
  out: C(Nx/data/model, Ny, Nz)"""),
}


# --- golden snapshots --------------------------------------------------------

@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_searched_schedules(key):
    token, want = GOLDEN[key]
    cand = ScheduleCandidate.from_plan_key(token)
    assert cand.build_schedule().describe() == want, (
        f"searched stage structure of {key} changed — if intentional, "
        "update the golden AND re-verify numerics + cost rankings")


# --- plan tokens -------------------------------------------------------------

def test_token_round_trip_exact():
    for token, _ in GOLDEN.values():
        cand = ScheduleCandidate.from_plan_key(token)
        assert cand.plan_key == token
        again = ScheduleCandidate.from_plan_key(cand.plan_key)
        assert again == cand
        assert (again.build_schedule().describe()
                == cand.build_schedule().describe())


def test_token_round_trip_enumerated():
    cands = cand_lib.enumerate_schedule_candidates((64, 64, 4), SIZES)
    assert cands, "enumerator returned nothing"
    for cand in cands[:200]:
        assert (ScheduleCandidate.from_plan_key(cand.plan_key).plan_key
                == cand.plan_key)


def test_grad_token_round_trip():
    cand = ScheduleCandidate.from_plan_key(MIXED_KEY)
    grad = dataclasses.replace(cand, problem="c2c_grad")
    assert grad.plan_key.endswith("|c2c_grad:")  # |problem:strategy tail
    back = cand_lib.candidate_from_plan_key(grad.plan_key)
    assert back == grad


def test_bad_tokens_raise_valueerror():
    for bad in ("sched:", "sched:pencil[data,model]",
                "sched:pencil[data,model]|k1/matmul/natural/alltoall"
                "/pipelined|f9", MIXED_KEY + ";t5s0c1h2"):
        with pytest.raises(ValueError):
            ScheduleCandidate.from_plan_key(bad)


# --- enumerator + dedup (satellite: no candidate measured twice) -------------

def test_enumerator_excludes_fixed_expressible():
    cands = cand_lib.enumerate_schedule_candidates((64, 64, 64), SIZES)
    for cand in cands:
        assert cand.as_options_candidate() is None, (
            f"{cand.plan_key} is expressible by a fixed builder and "
            "should have been excluded")


def test_homogeneous_overrides_normalize_to_options_candidate():
    # per-stage (ring, ring) with matching Ks is the same pipeline as
    # the scalar transpose_impl="ring" knob — satellite-1's double-
    # measurement bug in spec form
    fixed = cand_lib.Candidate(
        PENCIL, FFTOptions(overlap_k=1, transpose_impl="ring",
                           output_layout="spectral"))
    wrapped = ScheduleCandidate.from_candidate(fixed)
    redundant = dataclasses.replace(
        wrapped, stages=tuple(
            dataclasses.replace(sp, impl="ring", k=1)
            if sp.comm is not None else sp for sp in wrapped.stages))
    eq = redundant.as_options_candidate()
    assert eq is not None and eq.plan_key == fixed.plan_key
    deduped = cand_lib.dedupe_candidates([fixed, redundant, wrapped])
    assert [c.plan_key for c in deduped] == [fixed.plan_key]


def test_dedupe_no_duplicate_plan_keys():
    fixed = cand_lib.enumerate_candidates(GATE_SHAPE, SIZES)
    searched = cand_lib.enumerate_schedule_candidates(GATE_SHAPE, SIZES)
    deduped = cand_lib.dedupe_candidates(list(fixed) + list(searched))
    keys = [c.plan_key for c in deduped]
    assert len(keys) == len(set(keys))
    # dedup must not drop the distinct pipelines
    assert len(deduped) >= len(fixed)


def test_enumerator_prunes_invalid_chunking():
    # z=4 over model=4 leaves one z plane per device: any candidate
    # whose layouts demand a finer split must have been pruned
    for cand in cand_lib.enumerate_schedule_candidates((8, 8, 4), SIZES):
        cand.validate((8, 8, 4), SIZES)


def test_ring_on_folded_communicator_rejected():
    slab = ScheduleCandidate.from_plan_key(SPLIT_KEY)
    ringy = dataclasses.replace(
        slab, stages=tuple(
            dataclasses.replace(sp, impl="ring") if sp.comm is not None
            else sp for sp in slab.stages))
    with pytest.raises(ValueError):
        ringy.validate((64, 64, 8), SIZES)


# --- per-stage knob threading ------------------------------------------------

def test_stage_override_resolution():
    opts = FFTOptions(overlap_k=4, transpose_impl="alltoall")
    sched = ScheduleCandidate.from_plan_key(MIXED_KEY).build_schedule()
    st_ring, st_a2a = sched.stages[0], sched.stages[1]
    assert schedule_lib.stage_transpose_impl(st_ring, opts) == "ring"
    assert schedule_lib.stage_transpose_impl(st_a2a, opts) == "alltoall"
    assert schedule_lib.stage_overlap_k(st_a2a, opts) == 2
    # None-override stages inherit the plan options
    assert schedule_lib.stage_overlap_k(st_ring, opts) == 4


def test_effective_k_respects_stage_overrides():
    sched = ScheduleCandidate.from_plan_key(MIXED_KEY).build_schedule()
    # base K=1, stage 1 overrides K=2 (x extent 512/2 divides)
    assert tuple(sched.effective_k(GATE_SHAPE, SIZES, 1)) == (1, 2)
    # the override also caps: indivisible extents still collapse to 1
    assert sched.effective_k((512, 512, 2), {"data": 2, "model": 1},
                             1)[1] == 2


# --- adjoint of searched schedules -------------------------------------------

def test_adjoint_preserves_overrides_and_layouts():
    for token, _ in GOLDEN.values():
        sched = ScheduleCandidate.from_plan_key(token).build_schedule()
        adj = adjoint_schedule(sched)
        # the adjoint must consume the forward's output layout and emit
        # its input layout — any searched transpose order included
        assert str(adj.layout_in) == str(sched.layout_out)
        assert str(adj.layout_out) == str(sched.layout_in)
        fwd_overrides = sorted(
            (str(st.transpose_impl), st.overlap_k or 0)
            for st in sched.stages if st.comm_axis is not None)
        adj_overrides = sorted(
            (str(st.transpose_impl), st.overlap_k or 0)
            for st in adj.stages if st.comm_axis is not None)
        assert fwd_overrides == adj_overrides


def test_predicted_collectives_forward_and_adjoint():
    cand = ScheduleCandidate.from_plan_key(MIXED_KEY)
    sched = cand.build_schedule()
    shape = (32, 32, 4)
    pred = cost_model.predicted_collectives(sched, shape, SIZES, cand.opts)
    # stage 0: ring over data (P=2), K_eff 1 -> 1 permute round;
    # stage 1: alltoall K=2 -> 2 all-to-alls
    assert pred == {"all-to-all": 2, "collective-permute": 1}
    adj = adjoint_schedule(sched)
    assert (cost_model.predicted_collectives(adj, shape, SIZES, cand.opts)
            == pred)


# --- per-stage cost model ----------------------------------------------------

def test_searched_cost_rows_carry_impls():
    cand = ScheduleCandidate.from_plan_key(MIXED_KEY)
    rows = cost_model.per_stage_costs(GATE_SHAPE, cand, SIZES)
    impls = [r["impl"] for r in rows if r.get("collective_s")]
    assert impls == ["ring", "alltoall"]
    cost = cost_model.analytic_cost(GATE_SHAPE, cand, SIZES)
    assert cost.total_s > 0


def test_mixed_beats_homogeneous_at_gate_point():
    """The deterministic win regime the search exists for: stage 0's
    chunk axis (z, one plane per model rank) cannot split, so a
    homogeneous K leaves stage 0's all-to-all unhidden while a
    homogeneous ring pays P-1 latencies on the big communicator.  The
    mixed plan takes ring where chunking is impossible and pipelined
    alltoall where it is not."""
    mixed = ScheduleCandidate.from_plan_key(MIXED_KEY)
    base = mixed.opts
    hom_ring = dataclasses.replace(
        mixed, opts=dataclasses.replace(base, transpose_impl="ring"),
        stages=tuple(dataclasses.replace(sp, impl=None, k=None)
                     for sp in mixed.stages))
    hom_a2a_k2 = dataclasses.replace(
        mixed, opts=dataclasses.replace(base, overlap_k=2),
        stages=tuple(dataclasses.replace(sp, impl=None, k=None)
                     for sp in mixed.stages))
    t = {c: cost_model.analytic_cost(GATE_SHAPE, c, SIZES).total_s
         for c in (mixed, hom_ring, hom_a2a_k2)}
    assert t[mixed] < t[hom_ring]
    assert t[mixed] < t[hom_a2a_k2]


def test_fixed_candidate_costs_unchanged():
    """The legacy options-space cost formula is pinned bit-identical:
    adding the per-stage combine for searched candidates must not move
    any fixed candidate's score (wisdom files rank with these)."""
    fixed = cand_lib.Candidate(
        PENCIL, FFTOptions(overlap_k=2, output_layout="spectral"))
    cost = cost_model.analytic_cost((64, 64, 64), fixed, SIZES)
    again = cost_model.analytic_cost((64, 64, 64), fixed, SIZES)
    assert cost.total_s == again.total_s
    assert not getattr(fixed, "is_schedule", False)


# --- planner + wisdom --------------------------------------------------------

def test_tune_schedule_search_model_mode(tmp_path):
    wpath = str(tmp_path / "w.json")
    r = planner.tune(GATE_SHAPE, axis_sizes=SIZES, mode="model",
                     search="schedule", wisdom_path=wpath)
    assert r.source == "model"
    labels = {row["label"] for row in r.ranked}
    assert any(lb.startswith("sched:") for lb in labels), (
        "schedule search produced no searched candidates in the ranking")
    # wisdom round trip: the stored entry reconstructs the same plan
    r2 = planner.tune(GATE_SHAPE, axis_sizes=SIZES, mode="wisdom",
                      search="schedule", wisdom_path=wpath)
    assert r2.source == "wisdom"
    if r.schedule is not None:
        assert r2.schedule is not None
        assert r2.schedule.plan_key == r.schedule.plan_key


def test_tune_schedule_search_rejects_r2c():
    with pytest.raises(ValueError):
        planner.tune((32, 32, 32), axis_sizes=SIZES, mode="model",
                     search="schedule", problem="r2c")


def test_wisdom_entry_schedule_round_trip(tmp_path):
    cand = ScheduleCandidate.from_plan_key(MIXED_KEY)
    entry = wisdom_lib.WisdomEntry.from_candidate(cand, "model",
                                                  model_s=1e-4)
    assert entry.schedule == MIXED_KEY
    back = wisdom_lib.WisdomEntry.from_json(entry.to_json()).candidate()
    assert back == cand
    # persists through the file format
    wpath = str(tmp_path / "w.json")
    wisdom_lib.merge_entries(wpath, {"k": entry})
    loaded = wisdom_lib.Wisdom.load(wpath).entries["k"]
    assert loaded.candidate().plan_key == MIXED_KEY


def test_legacy_wisdom_entries_still_load(tmp_path):
    """Wisdom written before the schedule field existed must keep
    loading, merging and planning — the on-disk compat contract."""
    legacy = {"version": 1, "entries": {"legacy-key": {
        "decomp_kind": "pencil", "decomp_axes": ["data", "model"],
        "opts": {"overlap_k": 2, "transpose_impl": "alltoall",
                 "output_layout": "spectral"},
        "source": "measure", "measured_s": 5e-5}}}
    p = tmp_path / "legacy.json"
    p.write_text(json.dumps(legacy))
    w = wisdom_lib.Wisdom.load(str(p))
    cand = w.entries["legacy-key"].candidate()
    assert not getattr(cand, "is_schedule", False)
    assert cand.decomp.kind == "pencil"
    assert cand.opts.overlap_k == 2
    assert build_schedule(cand.decomp, cand.opts).describe()
    # merging a schedule entry alongside leaves the legacy entry intact
    sched_entry = wisdom_lib.WisdomEntry.from_candidate(
        ScheduleCandidate.from_plan_key(MIXED_KEY), "model", model_s=1e-4)
    wisdom_lib.merge_entries(str(p), {"sched-key": sched_entry})
    w2 = wisdom_lib.Wisdom.load(str(p))
    assert w2.entries["legacy-key"].measured_s == 5e-5
    assert w2.entries["sched-key"].candidate().plan_key == MIXED_KEY


def test_wisdom_cli_renders_schedule_entries(tmp_path, capsys):
    wpath = str(tmp_path / "w.json")
    entry = wisdom_lib.WisdomEntry.from_candidate(
        ScheduleCandidate.from_plan_key(MIXED_KEY), "model", model_s=1e-4)
    wisdom_lib.merge_entries(wpath, {"some-key": entry})
    assert wisdom_lib._main(["show", wpath]) == 0
    out = capsys.readouterr().out
    assert "<unreadable entry>" not in out
    assert "stages: x-fft+xy[ring,K=1] -> y-fft+yz[alltoall,K=2] " \
           "-> z-fft" in out
    assert wisdom_lib._main(["stats", wpath]) == 0
    out = capsys.readouterr().out
    assert "/sched" in out and "searched:   1 schedule-keyed entry" in out


# --- multi-device numerics ---------------------------------------------------

def test_searched_schedules_execute_and_invert():
    """Forward == np.fft.fftn and inverse round-trips for pipelines the
    fixed builders cannot produce (fused natural, split slab, mixed
    impls), plus bitwise parity with the fixed builder where the spaces
    overlap."""
    run_multidevice(f"""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.tuning.candidates import Candidate, ScheduleCandidate

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = (16, 16, 8)
rng = np.random.default_rng(0)
x = (rng.standard_normal(shape)
     + 1j * rng.standard_normal(shape)).astype(np.complex64)
ref = np.fft.fftn(x).astype(np.complex64)

for token in [{MIXED_KEY!r}, {FUSED_KEY!r}, {SPLIT_KEY!r}]:
    cand = ScheduleCandidate.from_plan_key(token)
    plan = Croft3D(shape, mesh=mesh, schedule=cand)
    xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
    y = plan.forward(xd)
    got = np.asarray(jax.device_get(y))
    err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert err < 1e-4, (token, err)
    xb = np.asarray(jax.device_get(plan.inverse(y)))
    rerr = np.max(np.abs(xb - x)) / np.max(np.abs(x))
    assert rerr < 1e-4, (token, rerr)

# bitwise parity: a fixed plan wrapped as a (no-override) schedule
# candidate must compile to the numerically identical program
fixed = Candidate(Decomposition("pencil", ("data", "model")),
                  FFTOptions(overlap_k=2, output_layout="spectral"))
wrapped = ScheduleCandidate.from_candidate(fixed)
pf = Croft3D(shape, mesh, fixed.decomp, fixed.opts)
ps = Croft3D(shape, mesh=mesh, schedule=wrapped)
xd = jax.device_put(jnp.asarray(x), pf.input_sharding)
assert bool(jnp.array_equal(pf.forward(xd), ps.forward(xd))), \\
    "wrapped fixed pipeline diverged bitwise from the fixed builder"
print("OK")
""")


def test_searched_schedule_differentiates():
    """grad through a searched mixed-impl plan matches the spectral
    Parseval identity; the custom VJP replays the adjoint schedule, so
    this exercises adjoint layout validation end to end."""
    run_multidevice(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D
from repro.tuning.candidates import ScheduleCandidate

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = (16, 16, 8)
plan = Croft3D(shape, mesh=mesh,
               schedule=ScheduleCandidate.from_plan_key({MIXED_KEY!r}))
rng = np.random.default_rng(1)
x = jnp.asarray((rng.standard_normal(shape)
                 + 1j * rng.standard_normal(shape)).astype(np.complex64))
x = jax.device_put(x, plan.input_sharding)

def loss(v):
    y = plan.forward(v)
    return jnp.sum(jnp.real(y * jnp.conj(y)))

g = jax.grad(loss)(x)
# JAX's complex-grad convention: grad sum|Fx|^2 = 2 conj(F^H F x)
# = 2 N conj(x) for the unnormalized DFT (Parseval)
n = float(np.prod(shape))
np.testing.assert_allclose(np.asarray(jax.device_get(g)),
                           2 * n * np.conj(np.asarray(jax.device_get(x))),
                           rtol=1e-3, atol=1e-3)
print("OK")
""")


def test_ring_round_callback_and_instrument_rounds():
    """run_schedule's ring_round_cb sees every ppermute round (1..P-1)
    and an identity callback leaves the numerics untouched; the obs
    re-driver emits per-round ring spans."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.compat import shard_map
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.core import schedule as schedule_lib
from repro.core.distributed import build_schedule
from repro.obs import instrument
from repro.tuning.measure import _random_input

mesh = jax.make_mesh((2, 4), ("data", "model"))
dec = Decomposition("pencil", ("data", "model"))
opts = FFTOptions(overlap_k=1, transpose_impl="ring",
                  output_layout="spectral")
sched = build_schedule(dec, opts)
shape = (16, 16, 8)

seen = []
def cb(rnd, piece):
    seen.append(rnd)
    return piece

def drive(v, rcb):
    def body(blk):
        return schedule_lib.run_schedule(blk, sched, opts,
                                         ring_round_cb=rcb)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=sched.layout_in.partition_spec(),
        out_specs=sched.layout_out.partition_spec()))(v)

x = _random_input(shape, jnp.complex64,
                  jax.NamedSharding(mesh, sched.layout_in.partition_spec()))
y_cb = drive(x, cb)
y_plain = drive(x, None)
assert bool(jnp.array_equal(y_cb, y_plain)), \\
    "identity ring callback changed the numerics"
# stage 0 rings over data (P=2): round 1; stage 1 over model (P=4): 1..3
assert sorted(set(seen)) == [1, 2, 3], seen
assert seen.count(1) == 2, seen

plan = Croft3D(shape, mesh, dec, opts)
tracer = obs.enable()
xs = jax.device_put(x, plan.input_sharding)
_, summary = instrument.trace_forward(plan, xs, tracer=tracer, iters=1,
                                      label="ring")
rounds = {row["name"]: [r["round"] for r in row.get("rounds", [])]
          for row in summary["stages"] if row["comm_s"] > 0}
assert rounds == {"x-fft+xy": [1], "y-fft+yz": [1, 2, 3]}, rounds
names = {e["name"] for e in tracer.events()}
assert "s1:y-fft+yz:round[3]" in names
obs.disable()
print("OK")
""")


def test_tune_measure_schedule_search_end_to_end():
    """measure-mode schedule search on a live mesh: the winner builds,
    times, persists to wisdom, and a fresh tune reconstructs it."""
    run_multidevice("""
import os, tempfile
import jax, jax.numpy as jnp
from repro.core import Croft3D
from repro.tuning.planner import tune

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = (16, 16, 8)
wpath = os.path.join(tempfile.mkdtemp(), "w.json")
r = tune(shape, mesh, mode="measure", search="schedule", top_k=2,
         wisdom_path=wpath, measure_iters=2, measure_warmup=1)
assert r.measured_s is not None and r.measured_s > 0
plan = Croft3D.tuned(shape, mesh, mode="wisdom", wisdom_path=wpath)
assert plan.tune_result.source == "wisdom"
if r.schedule is not None:
    assert plan.schedule is not None
    assert plan.schedule.plan_key == r.schedule.plan_key
x = jnp.ones(shape, jnp.complex64)
x = jax.device_put(x, plan.input_sharding)
jax.block_until_ready(plan.forward(x))
print("OK")
""")
