"""Config registry and assigned-architecture dimensional exactness."""

import pytest

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config, shape_supported
from repro.configs.croft_fft import croft_128, croft_1024, paper_option


def test_registry_complete():
    assert len(ASSIGNED) == 10
    for arch in ASSIGNED:
        full = get_config(arch)
        smoke = get_config(arch, smoke=True)
        assert full.n_layers > smoke.n_layers
        assert smoke.param_count() < 1e7


def test_unknown_arch():
    with pytest.raises(KeyError):
        get_config("nope-7b")


# exact dims from the assignment table
SPEC = {
    "mixtral-8x22b": dict(L=56, d=6144, H=48, kv=8, ff=16384, v=32768),
    "deepseek-v2-236b": dict(L=60, d=5120, H=128, ff=1536, v=102400),
    "h2o-danube-3-4b": dict(L=24, d=3840, H=32, kv=8, ff=10240, v=32000),
    "gemma3-4b": dict(L=34, d=2560, H=8, kv=4, ff=10240, v=262144),
    "yi-34b": dict(L=60, d=7168, H=56, kv=8, ff=20480, v=64000),
    "yi-9b": dict(L=48, d=4096, H=32, kv=4, ff=11008, v=64000),
    "whisper-base": dict(L=6, d=512, H=8, kv=8, ff=2048, v=51865),
    "recurrentgemma-9b": dict(L=38, d=4096, H=16, kv=1, ff=12288, v=256000),
    "rwkv6-3b": dict(L=32, d=2560, ff=8960, v=65536),
    "paligemma-3b": dict(L=18, d=2048, H=8, kv=1, ff=16384, v=257216),
}


@pytest.mark.parametrize("arch", sorted(SPEC))
def test_assigned_dims_exact(arch):
    s = SPEC[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == s["L"]
    assert cfg.d_model == s["d"]
    assert cfg.vocab == s["v"]
    # find a representative layer
    spec0 = cfg.stages[-1].pattern[0]
    if arch == "deepseek-v2-236b":
        assert spec0.moe.d_ff_expert == s["ff"]
        assert spec0.moe.n_experts == 160 and spec0.moe.top_k == 6
        assert spec0.moe.n_shared == 2
        assert spec0.attn.kind == "mla" and spec0.attn.kv_lora_rank == 512
        assert spec0.attn.n_heads == 128
    elif arch == "mixtral-8x22b":
        assert spec0.moe.d_ff_expert == s["ff"]
        assert spec0.moe.n_experts == 8 and spec0.moe.top_k == 2
        assert spec0.attn.n_heads == s["H"]
        assert spec0.attn.n_kv_heads == s["kv"]
        assert spec0.attn.window is not None  # SWA
    elif arch == "rwkv6-3b":
        assert cfg.d_ff == s["ff"]
        assert spec0.mixer == "rwkv6"
    else:
        assert cfg.d_ff == s["ff"]
        if spec0.mixer == "attn":
            assert spec0.attn.n_heads == s["H"]
            assert spec0.attn.n_kv_heads == s["kv"]


def test_gemma3_pattern_5to1():
    cfg = get_config("gemma3-4b")
    pat = cfg.stages[0].pattern
    windows = [sp.attn.window for sp in pat]
    assert windows[:5] == [1024] * 5 and windows[5] is None
    assert cfg.n_layers == 34


def test_recurrentgemma_pattern_2to1():
    cfg = get_config("recurrentgemma-9b")
    pat = cfg.stages[0].pattern
    assert [sp.mixer for sp in pat] == ["rglru", "rglru", "attn"]
    assert cfg.n_layers == 38


def test_deepseek_first_layer_dense():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.stages[0].pattern[0].ffn == "swiglu"
    assert cfg.stages[0].repeat == 1
    assert cfg.stages[1].repeat == 59


def test_whisper_encoder_decoder():
    cfg = get_config("whisper-base")
    assert cfg.encoder is not None and cfg.encoder.n_layers == 6
    assert cfg.stages[0].pattern[0].cross_attn
    assert not cfg.encoder.layer.attn.causal


def test_shape_skip_rules():
    long = SHAPES["long_500k"]
    for arch, expect in [("mixtral-8x22b", True), ("rwkv6-3b", True),
                         ("gemma3-4b", True), ("recurrentgemma-9b", True),
                         ("h2o-danube-3-4b", True),
                         ("yi-34b", False), ("yi-9b", False),
                         ("deepseek-v2-236b", False),
                         ("whisper-base", False), ("paligemma-3b", False)]:
        ok, why = shape_supported(get_config(arch), long)
        assert ok == expect, (arch, why)
    # fnet is encoder-only: no decode shapes at all
    ok, _ = shape_supported(get_config("fnet-350m"), SHAPES["decode_32k"])
    assert not ok


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].lowers_serve_step


def test_croft_configs():
    assert croft_128().grid == (128,) * 3
    c = paper_option(croft_1024(), 4)
    assert c.opts.overlap_k == 2 and c.opts.plan_cache
    c1 = paper_option(croft_1024(), 1)
    assert c1.opts.overlap_k == 1 and not c1.opts.plan_cache
