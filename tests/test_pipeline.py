"""Pipeline parallelism: pipelined == sequential, gradients flow, bubble
accounting."""

import numpy as np
import pytest

from conftest import run_multidevice
from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(2, 8) == pytest.approx(1 / 9)
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)


def test_pipeline_matches_sequential_and_grads():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
L, B, D = 8, 16, 12
W = jnp.asarray(rng.randn(L, D, D).astype(np.float32) * 0.3)
bvec = jnp.asarray(rng.randn(L, D).astype(np.float32) * 0.1)
x = jnp.asarray(rng.randn(B, D).astype(np.float32))

def layer_fn(p, h):
    w, b = p
    return jnp.tanh(h @ w + b)

# sequential reference
ref = x
for l in range(L):
    ref = layer_fn((W[l], bvec[l]), ref)

out = pipeline_apply(layer_fn, (W, bvec), x, mesh=mesh, stage_axis="pod",
                     n_micro=4)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err

# gradients through the pipeline == sequential gradients
def loss_pipe(w):
    return jnp.sum(pipeline_apply(layer_fn, (w, bvec), x, mesh=mesh,
                                  stage_axis="pod", n_micro=4) ** 2)
def loss_seq(w):
    h = x
    for l in range(L):
        h = layer_fn((w[l], bvec[l]), h)
    return jnp.sum(h ** 2)
g_p = jax.grad(loss_pipe)(W)
g_s = jax.grad(loss_seq)(W)
gerr = float(jnp.max(jnp.abs(g_p - g_s)))
assert gerr < 1e-4, gerr
print("OK pipeline fwd err", err, "grad err", gerr)
""", n_devices=4)
